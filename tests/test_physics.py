"""Physics validation against analytic results (paper Fig. 4 & Sec. 8).

These run the classical reference Hamiltonian (cheap, exact couplings) -
the NEP-trained version of the same checks lives in examples/ where more
compute is acceptable.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hamiltonian import HeisenbergDMIModel
from repro.md.analysis import helix_pitch, spin_structure_factor
from repro.md.integrator import IntegratorConfig
from repro.md.lattice import simple_cubic
from repro.md.simulate import Simulation
from repro.md.state import init_state


def test_helix_pitch_energy_selection():
    """Static Fig. 4 check: among helices of every commensurate pitch, the
    energy minimum sits at the analytic lambda = 2 pi a / arctan(D/J)."""
    lat = simple_cubic()
    n = 16
    d_over_j = float(np.tan(2 * np.pi / 8))   # ground state: 8 sites/period
    ham = HeisenbergDMIModel(d0=0.0166 * d_over_j, gamma_j=0.0,
                             gamma_d=0.0)
    st0 = init_state(lat, (n, 2, 2), spin_init="ferro_z")
    from repro.md.neighbor import dense_neighbor_table
    tab = dense_neighbor_table(st0.pos, st0.box, 5.0, 12)
    energies = {}
    for k in (1, 2, 3, 4):                    # pitch = n/k sites
        st = init_state(lat, (n, 2, 2), spin_init="helix_x",
                        helix_pitch=n * lat.a / k)
        energies[k] = float(ham.energy(st.pos, st.spin, st.types, tab,
                                       st.box))
    assert min(energies, key=energies.get) == 2, energies


def test_helix_dynamically_stable_at_analytic_pitch():
    """Dynamic Fig. 4 check: the analytic-pitch helix survives damped
    thermal dynamics (no pitch drift) while a perturbation decays."""
    lat = simple_cubic()
    n = 16
    d_over_j = float(np.tan(2 * np.pi / 8))
    ham = HeisenbergDMIModel(d0=0.0166 * d_over_j, gamma_j=0.0,
                             gamma_d=0.0)
    st = init_state(lat, (n, 2, 2), spin_init="helix_x",
                    helix_pitch=8 * lat.a, key=jax.random.PRNGKey(0))
    noise = 0.1 * jax.random.normal(jax.random.PRNGKey(1), st.spin.shape)
    spin = st.spin + noise
    st = st._replace(spin=spin / jnp.linalg.norm(spin, axis=-1,
                                                 keepdims=True))
    cfg = IntegratorConfig(dt=4e-3, temperature=1.0, lattice_gamma=10.0,
                           spin_alpha=0.5)
    sim = Simulation(potential=ham, cfg=cfg, state=st,
                     masses=jnp.asarray(lat.masses),
                     magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0,
                     capacity=8)
    sim.run(400, jax.random.PRNGKey(2), chunk=100)
    sk = spin_structure_factor(sim.state.pos, sim.state.spin, sim.state.box,
                               n_bins=n, axis=0)
    kstar = int(jnp.argmax(sk[1:])) + 1
    assert kstar == 2, f"helix drifted to k={kstar}"


def test_pitch_formula():
    ham = HeisenbergDMIModel(j0=0.02, d0=0.02 * np.tan(2 * np.pi / 10))
    assert abs(ham.pitch(1.0) - 10.0) < 1e-9


def test_ferromagnet_stays_ferro_without_dmi():
    lat = simple_cubic()
    ham = HeisenbergDMIModel(d0=0.0)
    st = init_state(lat, (4, 4, 4), spin_init="ferro_z",
                    key=jax.random.PRNGKey(3))
    # NN sits at r/rc = 0.94 where fc ~ 0.01 suppresses J_eff to ~1e-4 eV;
    # T must sit well below that scale for the ferro state to persist
    cfg = IntegratorConfig(dt=2e-3, temperature=0.5, lattice_gamma=5.0,
                           spin_alpha=0.2)
    sim = Simulation(potential=ham, cfg=cfg, state=st,
                     masses=jnp.asarray(lat.masses),
                     magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0,
                     capacity=8)
    sim.run(200, jax.random.PRNGKey(4), chunk=50)
    mz = float(jnp.mean(sim.state.spin[:, 2]))
    assert mz > 0.9, f"ferro destabilized: <Sz> = {mz}"


def test_larmor_precession_frequency():
    """A single spin in a field B precesses at the Larmor frequency
    omega = gyro * B - validates the gyromagnetic units end-to-end."""
    from repro.md.integrator import ForceField, IntegratorConfig, make_step
    from repro.md.state import SpinLatticeState
    from repro.utils import units
    b_z = 20.0  # Tesla
    moment = 1.16
    field_e = moment * units.MU_B * b_z
    cfg = IntegratorConfig(dt=1e-3, moment=moment, frozen_lattice=True)

    def evaluate(pos, spin):
        return ForceField(energy=jnp.zeros(()), force=jnp.zeros_like(pos),
                          field=jnp.tile(jnp.asarray([[0.0, 0.0, field_e]]),
                                         (pos.shape[0], 1)))

    step = make_step(evaluate, cfg, jnp.asarray([55.0]),
                     jnp.asarray([True]))
    state = SpinLatticeState(
        pos=jnp.zeros((1, 3)), vel=jnp.zeros((1, 3)),
        spin=jnp.asarray([[1.0, 0.0, 0.0]]),
        types=jnp.zeros((1,), jnp.int32), box=jnp.ones((3,)) * 100,
        step=jnp.asarray(0))
    ff = evaluate(state.pos, state.spin)
    n_steps = 200
    phases = []
    for i in range(n_steps):
        state, ff = step(state, ff, jax.random.PRNGKey(0))
        phases.append(float(np.arctan2(float(state.spin[0, 1]),
                                       float(state.spin[0, 0]))))
    # precession about z: unwrapped phase advances at -omega_Larmor
    dphi = np.diff(np.unwrap(np.asarray(phases)))
    omega = abs(float(np.mean(dphi))) / cfg.dt   # rad / ps
    expect = units.GYRO * b_z                    # Larmor
    assert abs(omega - expect) / expect < 1e-3, (omega, expect)
