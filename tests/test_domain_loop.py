"""Sharded fused loop correctness: parity vs the single-device fused driver.

The acceptance tests for the shard_map domain-decomposed hot loop
(repro.md.simulate.SimulationSharded + repro.parallel.domain):

* f64 trajectory parity (subprocess with 4 forced host devices, like
  test_domain.py) between the sharded loop - in-scan rebuild WITH cell
  migration across devices, one position halo per drift, adjoint-halo
  force fold-back - and the single-device fused driver, for BOTH
  potentials (Heisenberg-DMI with midpoint iterations, autodiff NEP-SPIN),
  each spanning at least one migration rebuild;
* halo-adjoint exactness: distributed forces and effective fields equal
  the single-device ``jax.grad`` forces at machine precision;
* replica axis composed with the spatial mesh: identical NVE replicas stay
  bitwise identical and track the unreplicated sharded run;
* migration overflow fails LOUDLY: the in-scan counter trips and the
  driver raises at the chunk boundary (no silent atom drops);
* the trace-time exchange ledger shows exactly ONE position halo per
  drift.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
jax.config.update("jax_enable_x64", True)
import json
import numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.descriptor import NEPSpinSpec
from repro.core.hamiltonian import HeisenbergDMIModel
from repro.core.potential import NEPSpinPotential, init_params
from repro.md.integrator import IntegratorConfig
from repro.md.lattice import simple_cubic
from repro.md.simulate import Simulation, SimulationSharded
from repro.md.state import init_state
from repro.parallel.halo import TRACE

lat = simple_cubic()
masses = jnp.asarray(lat.masses)
magnetic = jnp.asarray(lat.moments) > 0
kw = dict(masses=masses, magnetic=magnetic, cutoff=5.0, capacity=32,
          skin=0.2)
st = init_state(lat, (8, 8, 8), temperature=400.0, spin_init="random",
                key=jax.random.PRNGKey(7))
mesh2 = Mesh(np.asarray(jax.devices()[:2]), ("sx",))
mesh4 = Mesh(np.asarray(jax.devices()).reshape(2, 2), ("sx", "sy"))
out = {}


def parity(name, potential, cfg, n_steps, mesh, axis_map):
    flat = Simulation(potential=potential, cfg=cfg, state=st, **kw)
    TRACE.reset()
    sh = SimulationSharded(potential=potential, cfg=cfg, state=st,
                           mesh=mesh, axis_map=axis_map, **kw)
    # halo-adjoint exactness at step 0: the distributed gradient (forces
    # via the explicit adjoint-halo fold-back, H_eff via the automatic
    # exchange adjoint) against whole-system jax.grad
    res = {
        "e0": abs(float(flat.energy) - float(sh.energy)),
        "f0": float(jnp.abs(flat._ff.force - sh._ff.force).max()),
        "h0": float(jnp.abs(flat._ff.field - sh._ff.field).max()),
    }
    flat.run(n_steps, jax.random.PRNGKey(1), chunk=10)
    sh.run(n_steps, jax.random.PRNGKey(1), chunk=10)
    res.update({
        "pos": float(jnp.abs(flat.state.pos - sh.state.pos).max()),
        "vel": float(jnp.abs(flat.state.vel - sh.state.vel).max()),
        "spin": float(jnp.abs(flat.state.spin - sh.state.spin).max()),
        "rebuilds_flat": flat.n_rebuilds,
        "rebuilds_sharded": sh.n_rebuilds,
        "migrated": sh.n_migrated,
        "drift_pos_exchanges": TRACE.counts.get("drift-pos", 0),
        "chunk_cache": len(sh._chunk_cache),
    })
    out[name] = res


parity("heisenberg", HeisenbergDMIModel(d0=0.008, ka=0.001),
       IntegratorConfig(dt=2e-3, midpoint=True, midpoint_iters=2),
       60, mesh2, ("sx", None, None))
spec = NEPSpinSpec(l_max=2, n_ang=2, n_rad=4, n_spin=2, basis_size=6)
params = init_params(spec, jax.random.PRNGKey(0), dtype=jnp.float64)
parity("nep", NEPSpinPotential(spec, params, use_kernel=False),
       IntegratorConfig(dt=2e-3), 30, mesh4, ("sx", "sy", None))

# ---- replica axis composed with the spatial mesh --------------------------
ham = HeisenbergDMIModel(d0=0.008)
cfg = IntegratorConfig(dt=2e-3)
B = jnp.asarray([0.0, 0.0, 0.5])
meshr = Mesh(np.asarray(jax.devices()).reshape(2, 2), ("replica", "sx"))
shr = SimulationSharded(potential=ham, cfg=cfg, state=st, mesh=meshr,
                        axis_map=("sx", None, None), field=B, replicas=2,
                        **kw)
shr.run(20, jax.random.PRNGKey(3), chunk=10,
        temperature=jnp.zeros(2))        # NVE: keys drawn but noise-free
sh1 = SimulationSharded(potential=ham, cfg=cfg, state=st, mesh=mesh2,
                        axis_map=("sx", None, None), field=B, **kw)
sh1.run(20, jax.random.PRNGKey(3), chunk=10, temperature=0.0)
out["replica"] = {
    "identical_pos": float(jnp.abs(shr.state.pos[0]
                                   - shr.state.pos[1]).max()),
    "identical_spin": float(jnp.abs(shr.state.spin[0]
                                    - shr.state.spin[1]).max()),
    "vs_unreplicated": float(jnp.abs(shr.state.pos[0]
                                     - sh1.state.pos).max()),
    "trace_shape": list(shr.trace.energy.shape),
    "mag_shape": list(shr.trace.magnetization.shape),
}

# ---- migration overflow counts, never drops silently ----------------------
from repro.parallel.domain import DomainSpec, migrate_cells, pack_domain

dspec = DomainSpec(cells=(3, 3, 3), capacity=3, cutoff=5.0,
                   box=(18.0, 18.0, 18.0), axis_map=(None, None, None),
                   skin=0.2)
# 4 atoms headed for the same cell (capacity 3) + 1 atom two cells away
# from its binned slot (skin violation): 1 overflow + 1 out-of-reach
pos = np.asarray([[1.0, 1.0, 1.0], [2.0, 2.0, 2.0], [3.0, 3.0, 3.0],
                  [7.0, 1.0, 1.0], [13.0, 1.0, 1.0]])
zeros = np.zeros_like(pos)
types = np.zeros(5, np.int32)
dstate, extras = pack_domain(dspec, pos, zeros, zeros, types,
                             extras={"aid": np.arange(5, dtype=np.int32)})
new_pos = jnp.asarray(np.asarray(dstate.pos))
# move atom 3 (cell x=1) and atom 4 (cell x=2) into cell (0,0,0)'s column
flatten = np.asarray(dstate.types).reshape(-1)
aidf = np.asarray(extras["aid"]).reshape(-1)
posf = np.asarray(dstate.pos).reshape(-1, 3).copy()
posf[np.nonzero(aidf == 3)[0][0]] = [4.0, 4.0, 4.0]    # 1-cell hop: legal
posf[np.nonzero(aidf == 4)[0][0]] = [1.5, 1.5, 1.5]    # 2-cell jump: lost
new_pos = jnp.asarray(posf.reshape(dstate.pos.shape))
p2, v2, s2, t2, a2, moved, dropped = jax.jit(
    lambda p, v, s, t, a: migrate_cells(dspec, (3, 3, 3), p, v, s, t, a))(
        new_pos, dstate.vel, dstate.spin, dstate.types, extras["aid"])
out["overflow"] = {
    "dropped": int(dropped),
    "moved": int(moved),
    "survivors": int(jnp.sum(t2 >= 0)),
}
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def domain_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1800,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


@pytest.mark.parametrize("pot", ["heisenberg", "nep"])
def test_sharded_matches_fused_f64(domain_result, pot):
    """Trajectory parity across >=1 in-scan rebuild WITH migration."""
    res = domain_result[pot]
    assert res["rebuilds_sharded"] >= 1, res
    assert res["rebuilds_flat"] >= 1, res
    assert res["migrated"] > 0, res
    for fld in ("pos", "vel", "spin"):
        assert res[fld] < 1e-9, (pot, res)


@pytest.mark.parametrize("pot", ["heisenberg", "nep"])
def test_halo_adjoint_matches_grad(domain_result, pot):
    """Distributed forces (explicit adjoint-halo fold-back) and effective
    fields (automatic exchange adjoint) equal single-device jax.grad."""
    res = domain_result[pot]
    assert res["e0"] < 1e-10, res
    assert res["f0"] < 1e-11, res
    assert res["h0"] < 1e-11, res


@pytest.mark.parametrize("pot", ["heisenberg", "nep"])
def test_one_position_halo_per_drift(domain_result, pot):
    """The gather->compute contract, distributed: the traced step body
    contains exactly ONE position halo exchange, and one compiled chunk
    serves the whole run."""
    res = domain_result[pot]
    assert res["drift_pos_exchanges"] == 1, res
    assert res["chunk_cache"] == 1, res


def test_replicas_ride_sharded_loop(domain_result):
    res = domain_result["replica"]
    assert res["identical_pos"] == 0.0, res
    assert res["identical_spin"] == 0.0, res
    assert res["vs_unreplicated"] < 1e-12, res
    assert res["trace_shape"] == [2, 2], res      # (chunks, replicas)
    assert res["mag_shape"] == [2, 2, 3], res


def test_migration_overflow_counted_not_silent(domain_result):
    """Capacity overflow and out-of-reach jumps are counted: 4 atoms into
    a 3-slot cell (1 overflow) + one 2-cell jump (1 lost)."""
    res = domain_result["overflow"]
    assert res["dropped"] == 2, res
    assert res["survivors"] == 3, res


def test_overflow_raises_at_chunk_boundary():
    """The driver refuses to continue once the in-scan counter trips."""
    from repro.md.simulate import SimulationSharded
    from repro.core.hamiltonian import HeisenbergDMIModel
    from repro.md.integrator import IntegratorConfig
    from repro.md.lattice import simple_cubic
    from repro.md.state import init_state

    lat = simple_cubic()
    st = init_state(lat, (8, 8, 8), temperature=300.0, spin_init="helix_x",
                    key=jax.random.PRNGKey(0))
    sim = SimulationSharded(
        potential=HeisenbergDMIModel(d0=0.01), cfg=IntegratorConfig(),
        state=st, masses=jnp.asarray(lat.masses),
        magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0, capacity=32,
        skin=0.2)
    sim._carry = sim._carry._replace(n_dropped=jnp.asarray(3, jnp.int32))
    with pytest.raises(RuntimeError, match="overflow"):
        sim._check_dropped()


def test_single_device_mesh_matches_flat():
    """On one device the sharded loop degenerates cleanly (ppermute is the
    identity) and tracks the flat fused driver."""
    from repro.md.simulate import Simulation, SimulationSharded
    from repro.core.hamiltonian import HeisenbergDMIModel
    from repro.md.integrator import IntegratorConfig
    from repro.md.lattice import simple_cubic
    from repro.md.state import init_state

    lat = simple_cubic()
    st = init_state(lat, (8, 8, 8), temperature=400.0, spin_init="helix_x",
                    key=jax.random.PRNGKey(2))
    kw = dict(potential=HeisenbergDMIModel(d0=0.01),
              cfg=IntegratorConfig(dt=2e-3), state=st,
              masses=jnp.asarray(lat.masses),
              magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0,
              capacity=32, skin=0.2)
    flat = Simulation(**kw)
    sh = SimulationSharded(**kw)
    flat.run(20, jax.random.PRNGKey(1), chunk=10)
    sh.run(20, jax.random.PRNGKey(1), chunk=10)
    tol = 1e-9 if jax.config.jax_enable_x64 else 1e-3
    np.testing.assert_allclose(np.asarray(sh.state.pos),
                               np.asarray(flat.state.pos), atol=tol)
    assert np.isfinite(sh.trace.energy).all()
    assert sh.n_rebuilds >= 1
