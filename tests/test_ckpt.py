"""Checkpoint/restart fault-tolerance tests."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (latest_step, load_checkpoint,
                                   save_checkpoint, sweep_tmp)
from repro.ckpt.elastic import StragglerPolicy, run_resumable, straggler_chunks


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (8, 16)),
            "b": {"c": jax.random.normal(k2, (4,)),
                  "d": jnp.asarray(3, jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 7, t)
    loaded, step = load_checkpoint(str(tmp_path), t)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    t = _tree(jax.random.PRNGKey(1))
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, t, keep=3)
    assert latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3


def test_partial_checkpoint_invisible(tmp_path):
    """A checkpoint without a manifest (simulated crash mid-write) must be
    ignored by latest_step."""
    t = _tree(jax.random.PRNGKey(2))
    save_checkpoint(str(tmp_path), 1, t)
    # fake a crashed write: directory with shards but no manifest
    crash = tmp_path / "step_000000002"
    crash.mkdir()
    (crash / "shard_00000.npz").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) == 1
    loaded, step = load_checkpoint(str(tmp_path), t)
    assert step == 1


def test_incompatible_tree_rejected(tmp_path):
    t = _tree(jax.random.PRNGKey(3))
    save_checkpoint(str(tmp_path), 1, t)
    with pytest.raises(AssertionError):
        load_checkpoint(str(tmp_path), {"only": t["a"]})


def test_run_resumable_restores(tmp_path):
    calls = []

    def step_fn(state, batch):
        calls.append(batch)
        return {"x": state["x"] + 1}

    state = {"x": jnp.asarray(0)}
    # first run: 10 steps, ckpt every 4 -> last complete at step 7 (idx)
    s1, _ = run_resumable(step_fn, state, 10, str(tmp_path), every=4,
                          batch_fn=lambda i: i, async_save=False)
    assert int(s1["x"]) == 10
    # simulate preemption + restart: resumes from step 9 checkpoint
    s2, start = run_resumable(step_fn, state, 12, str(tmp_path), every=4,
                              batch_fn=lambda i: i, async_save=False)
    assert start == 10          # resumed, not recomputed from 0
    assert int(s2["x"]) == 12


def test_gc_never_collects_pinned_step(tmp_path):
    """``pin=<step>`` exempts the supervisor's rollback target from GC no
    matter how many newer checkpoints land."""
    t = _tree(jax.random.PRNGKey(4))
    save_checkpoint(str(tmp_path), 1, t)
    for s in (2, 3, 4, 5, 6):
        save_checkpoint(str(tmp_path), s, t, keep=2, pin=1)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert "step_000000001" in kept, kept       # pinned survives
    assert len(kept) == 3                       # pin + newest keep=2
    loaded, step = load_checkpoint(str(tmp_path), t, step=1)
    assert step == 1


def test_stale_tmp_swept_on_next_save(tmp_path):
    """A ``step_*.tmp`` directory left by a crash mid-write is removed by
    the next save into the same directory."""
    stale = tmp_path / "step_000000009.tmp"
    stale.mkdir()
    (stale / "shard_00000.npz").write_bytes(b"partial")
    t = _tree(jax.random.PRNGKey(5))
    save_checkpoint(str(tmp_path), 10, t)
    assert not stale.exists()
    assert latest_step(str(tmp_path)) == 10
    # sweep_tmp is also callable directly (restart hygiene)
    stale.mkdir()
    assert sweep_tmp(str(tmp_path)) == [str(stale)]
    assert not stale.exists()


def _failing_save(tmp_path, step):
    """Async save doomed to fail: a FILE occupies the tmp dir path, so the
    worker thread's makedirs raises."""
    blocker = tmp_path / f"step_{step:09d}.tmp"
    blocker.write_bytes(b"not a directory")
    t = _tree(jax.random.PRNGKey(6))
    h = save_checkpoint(str(tmp_path), step, t, async_=True)
    while not h.done:          # wait for the worker without acknowledging
        pass
    return t, h


def test_async_write_failure_surfaces_on_join(tmp_path):
    _, h = _failing_save(tmp_path, 3)
    assert h.error is not None
    with pytest.raises(RuntimeError, match="async checkpoint write"):
        h.join()
    # joining acknowledged the failure: the next save is clean
    t = _tree(jax.random.PRNGKey(7))
    save_checkpoint(str(tmp_path / "clean"), 4, t)


def test_async_write_failure_surfaces_on_next_save(tmp_path):
    """An unjoined failed async write re-raises on the NEXT save so it can
    never silently become 'no newest checkpoint'."""
    t, h = _failing_save(tmp_path, 5)
    with pytest.raises(RuntimeError, match="previous async checkpoint"):
        save_checkpoint(str(tmp_path / "other"), 6, t)
    assert h.error is not None      # still inspectable after re-raise


def test_straggler_chunks():
    """Post-hoc straggler flagging over a run's per-chunk wall times."""
    walls = [1.0, 1.1, 0.9, 5.0, 1.0, 1.05]
    assert straggler_chunks(walls) == [3]
    # warmup (chunk 0 compiles) is never a straggler
    assert straggler_chunks([9.0, 1.0, 1.1, 0.9, 1.0]) == []
    # too few samples to call anyone slow
    assert straggler_chunks([1.0, 9.0], min_samples=4) == []


def test_straggler_policy():
    p = StragglerPolicy(window=20, threshold=1.5)
    flags = [p.record(0.1) for _ in range(15)]
    assert not any(flags)
    assert p.record(0.5)        # 5x median -> straggler


def test_elastic_md_redecompose():
    """Rescaling the MD domain decomposition preserves the atom set."""
    from repro.md.lattice import simple_cubic
    from repro.md.state import init_state
    from repro.parallel.domain import DomainSpec, pack_domain, unpack_domain
    from repro.ckpt.elastic import redecompose
    lat = simple_cubic()
    st = init_state(lat, (8, 8, 8), temperature=100.0,
                    key=jax.random.PRNGKey(0))
    box = tuple(float(b) for b in st.box)
    d1 = DomainSpec(cells=(4, 4, 4), capacity=16, cutoff=5.0, box=box)
    d2 = DomainSpec(cells=(8, 8, 8), capacity=8, cutoff=4.0, box=box)
    ds1 = pack_domain(d1, st.pos, st.vel, st.spin, st.types)
    ds2 = redecompose(d1, d2, ds1)
    p1, *_ = unpack_domain(ds1)
    p2, *_ = unpack_domain(ds2)
    assert sorted(map(tuple, np.round(p1, 6).tolist())) == \
        sorted(map(tuple, np.round(p2, 6).tolist()))
