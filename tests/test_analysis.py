"""Texture-analysis diagnostics: topological charge, helix pitch."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.md.analysis import (helix_pitch, magnetization,
                               spin_structure_factor, topological_charge,
                               topological_charge_grid)
from repro.md.lattice import simple_cubic
from repro.md.state import init_state


def _skyrmion_grid(n=32, radius=8.0, center=None):
    """Synthetic Bloch skyrmion on an n x n grid: Q = -1."""
    c = center or (n / 2, n / 2)
    x, y = np.meshgrid(np.arange(n) - c[0], np.arange(n) - c[1],
                       indexing="ij")
    r = np.sqrt(x * x + y * y)
    theta = np.pi * np.clip(r / radius, 0, 1)   # pi at center... build:
    theta = np.pi * (1 - np.clip(r / radius, 0, 1))  # core down, edge up
    phi = np.arctan2(y, x) + np.pi / 2          # Bloch winding
    s = np.stack([np.sin(theta) * np.cos(phi),
                  np.sin(theta) * np.sin(phi),
                  -np.cos(theta)], axis=-1)
    return jnp.asarray(s)


def test_skyrmion_charge_is_integer_one():
    s = _skyrmion_grid()
    q = float(topological_charge_grid(s))
    assert abs(abs(q) - 1.0) < 0.05, f"Q = {q}"


def test_ferromagnet_charge_zero():
    s = jnp.tile(jnp.asarray([0.0, 0.0, 1.0]), (16, 16, 1))
    assert abs(float(topological_charge_grid(s))) < 1e-9


def test_helix_pitch_detection():
    lat = simple_cubic()
    st = init_state(lat, (16, 4, 4), spin_init="helix_x",
                    helix_pitch=8 * lat.a)
    pitch = float(helix_pitch(st.pos, st.spin, st.box, axis=0, n_bins=16))
    assert abs(pitch - 8 * lat.a) < 1e-3


def test_structure_factor_peak():
    lat = simple_cubic()
    st = init_state(lat, (16, 4, 4), spin_init="helix_x",
                    helix_pitch=4 * lat.a)
    sk = spin_structure_factor(st.pos, st.spin, st.box, n_bins=16, axis=0)
    assert int(jnp.argmax(sk[1:])) + 1 == 4   # 4 periods in the box


def test_magnetization_of_helix_is_zero():
    lat = simple_cubic()
    st = init_state(lat, (8, 4, 4), spin_init="helix_x",
                    helix_pitch=4 * lat.a)
    m = np.asarray(magnetization(st.spin))
    assert np.abs(m).max() < 1e-6


def test_atom_positions_charge_projection():
    """topological_charge() (atom positions -> grid) agrees with the grid
    version for a texture painted onto a lattice."""
    lat = simple_cubic()
    st = init_state(lat, (16, 16, 1), spin_init="ferro_z")
    s = _skyrmion_grid(16, radius=6.0)
    spins = s.reshape(-1, 3)
    # positions were generated cell-major (x fastest? verify via binning)
    q = float(topological_charge(st.pos, spins[
        (np.asarray(st.pos[:, 0]) / lat.a).astype(int) * 16 +
        (np.asarray(st.pos[:, 1]) / lat.a).astype(int)],
        st.box, grid=(16, 16)))
    assert abs(abs(q) - 1.0) < 0.1
