"""Force/field correctness: autodiff vs finite differences; baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hamiltonian import HeisenbergDMIModel
from repro.core.potential import energy, energy_forces_field, init_params
from repro.md.lattice import simple_cubic
from repro.md.neighbor import dense_neighbor_table
from repro.md.state import init_state


@pytest.fixture(scope="module")
def system(small_spec):
    lat = simple_cubic()
    st = init_state(lat, (3, 3, 3), temperature=200.0, spin_init="random",
                    key=jax.random.PRNGKey(3))
    tab = dense_neighbor_table(st.pos, st.box, 5.0, 12)
    return st, tab


def _fd_check(efn, x, analytic, eps=3e-3, atol=2e-3):
    """Central-difference check on a few random components."""
    rng = np.random.default_rng(0)
    x_np = np.asarray(x, np.float64)
    for _ in range(6):
        i = rng.integers(x_np.shape[0])
        d = rng.integers(x_np.shape[-1])
        xp = x_np.copy(); xp[i, d] += eps
        xm = x_np.copy(); xm[i, d] -= eps
        fd = (float(efn(jnp.asarray(xp, x.dtype)))
              - float(efn(jnp.asarray(xm, x.dtype)))) / (2 * eps)
        got = float(analytic[i, d])
        assert abs(fd - got) < atol + 0.02 * abs(fd), \
            f"component ({i},{d}): fd {fd} vs analytic {got}"


def test_nep_forces_match_fd(system, small_spec, small_params):
    st, tab = system
    spec, params = small_spec, small_params
    e, f, h = energy_forces_field(spec, params, st.pos, st.spin, st.types,
                                  tab, st.box)
    _fd_check(lambda p: energy(spec, params, p, st.spin, st.types, tab,
                               st.box), st.pos, -f)


def test_nep_field_matches_fd(system, small_spec, small_params):
    st, tab = system
    spec, params = small_spec, small_params
    e, f, h = energy_forces_field(spec, params, st.pos, st.spin, st.types,
                                  tab, st.box)
    _fd_check(lambda s: energy(spec, params, st.pos, s, st.types, tab,
                               st.box), st.spin, -h)


def test_reference_hamiltonian_forces_fd(system):
    st, tab = system
    ham = HeisenbergDMIModel(d0=0.002, kpd=0.0005, ka=0.001)
    e, f, h = ham.energy_forces_field(st.pos, st.spin, st.types, tab,
                                      st.box)
    _fd_check(lambda p: ham.energy(p, st.spin, st.types, tab, st.box),
              st.pos, -f, atol=5e-3)


def test_zeeman_field_shift(system, small_spec, small_params):
    """Zeeman term: H_eff shifts by +mu_B*m*B exactly, energy by -m.B sum."""
    from repro.utils import units
    st, tab = system
    spec, params = small_spec, small_params
    mom = jnp.asarray([1.16])
    b = jnp.asarray([0.0, 0.0, 0.5])
    e0, f0, h0 = energy_forces_field(spec, params, st.pos, st.spin,
                                     st.types, tab, st.box, None, mom)
    e1, f1, h1 = energy_forces_field(spec, params, st.pos, st.spin,
                                     st.types, tab, st.box, b, mom)
    np.testing.assert_allclose(np.asarray(f0), np.asarray(f1), rtol=1e-5,
                               atol=1e-7)
    shift = np.asarray(h1 - h0)
    expect = units.MU_B * 1.16 * np.asarray(b)
    np.testing.assert_allclose(shift, np.broadcast_to(expect, shift.shape),
                               atol=1e-7)
    de = float(e1 - e0)
    expect_de = -units.MU_B * 1.16 * float(jnp.sum(st.spin[:, 2]))
    assert abs(de - expect_de) < 5e-4  # f32 sum roundoff on O(10 eV)


def test_helix_is_lower_than_ferro_with_dmi():
    """With bulk DMI the helix must beat the ferromagnet energetically -
    the physics behind Fig. 4."""
    lat = simple_cubic()
    ham = HeisenbergDMIModel(cutoff=5.0, d0=0.0166 * np.tan(2 * np.pi / 8),
                             gamma_d=0.0, gamma_j=0.0)
    # pitch of 8 sites fits the 8-cell box exactly
    st_f = init_state(lat, (8, 8, 8), spin_init="ferro_z")
    st_h = init_state(lat, (8, 8, 8), spin_init="helix_x",
                      helix_pitch=8 * lat.a)
    tab = dense_neighbor_table(st_f.pos, st_f.box, 5.0, 12)
    e_f = float(ham.energy(st_f.pos, st_f.spin, st_f.types, tab, st_f.box))
    e_h = float(ham.energy(st_h.pos, st_h.spin, st_h.types, tab, st_h.box))
    assert e_h < e_f
