"""Shared fixtures. NOTE: x64 and forced device counts are NOT set here -
tests needing them run subprocesses (see test_domain.py, test_precision.py)
so the in-process suite sees the default 1-device f32 environment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def b20_state():
    from repro.md.lattice import b20_fege
    from repro.md.state import init_state
    lat = b20_fege()
    st = init_state(lat, (2, 2, 2), temperature=300.0,
                    key=jax.random.PRNGKey(1))
    return lat, st


@pytest.fixture(scope="session")
def small_spec():
    from repro.core.descriptor import NEPSpinSpec
    return NEPSpinSpec(l_max=2, n_ang=2, n_rad=4, n_spin=2, basis_size=6)


@pytest.fixture(scope="session")
def small_params(small_spec):
    from repro.core.potential import init_params
    return init_params(small_spec, jax.random.PRNGKey(0), dtype=jnp.float32)
