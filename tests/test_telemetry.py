"""Telemetry acceptance: run-scoped halo ledger, health monitoring with
abort-and-resume, runlog + report, and the compile watchdog.

The PR-6 acceptance tests:

* the halo exchange ledger is RUN-scoped: two back-to-back runs report
  identical per-run counts/bytes (the process-global ``TRACE`` used to
  accumulate across runs - the latent bug this PR fixes);
* NaN injection mid-run (a schedule that goes non-finite after the first
  chunk) raises a structured :class:`HealthError` naming the last-good
  checkpoint, and restoring that checkpoint resumes a finite trajectory -
  on the flat plan in-process and on the 2-device sharded plan in a
  subprocess;
* a clean run passes energy-drift / spin-norm thresholds and lands its
  health signals in ``EngineTrace.health`` and the runlog;
* migration overflow routes through :class:`HealthError` with per-device
  drop counts and the offending chunk index;
* the compile watchdog observes ZERO recompiles across a schedule-driven
  sharded run (asserted from the runlog's per-chunk compile deltas);
* ``launch/report.py`` renders a runlog without error.
"""
import json
import math
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hamiltonian import HeisenbergDMIModel
from repro.ensemble import protocol
from repro.md.engine import Engine
from repro.md.integrator import IntegratorConfig
from repro.md.lattice import simple_cubic
from repro.md.state import init_state
from repro.parallel.plan import Sharded
from repro.telemetry import HealthConfig, Telemetry
from repro.telemetry.monitor import HealthError
from repro.telemetry.runlog import read_runlog


def _engine(plan=None, seed=3, temperature=None, field=None, **kw):
    lat = simple_cubic()
    st = init_state(lat, (4, 4, 4), temperature=300.0, spin_init="helix_x",
                    key=jax.random.PRNGKey(seed))
    return Engine(potential=HeisenbergDMIModel(d0=0.008),
                  cfg=IntegratorConfig(dt=2e-3, spin_alpha=0.05,
                                       lattice_gamma=1.0),
                  state=st, masses=jnp.asarray(lat.masses),
                  magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0,
                  capacity=8, skin=0.2, plan=plan, temperature=temperature,
                  field=field, observables=("energy", "magnetization"),
                  **kw)


def _nan_after(t_nan=0.021, hold=(0.0, 0.0, 5.0)):
    """Field schedule that goes NaN strictly after ``t_nan`` [ps]."""
    nan3 = [float("nan")] * 3
    return protocol.piecewise([0.0, t_nan, t_nan, 1.0],
                              [list(hold), list(hold), nan3, nan3])


# ---------------------------------------------------------------------------
# run-scoped halo ledger (the TRACE accumulation bug)
# ---------------------------------------------------------------------------

def test_halo_ledger_is_run_scoped():
    """Two identical back-to-back runs report identical per-run halo
    counts and bytes; the process-global TRACE keeps accumulating (it is
    only a deprecated tee target)."""
    from repro.parallel.halo import TRACE

    snaps = []
    global_before = dict(TRACE.counts)
    for seed in (3, 3):
        eng = _engine(plan=Sharded(), seed=seed)
        eng.run(20, jax.random.PRNGKey(1), chunk=10)
        snaps.append(eng.halo_ledger.snapshot())
    assert snaps[0] == snaps[1], snaps
    assert snaps[0]["counts"], "ledger recorded no exchanges"
    assert snaps[0]["bytes_per_step"] > 0, snaps[0]
    # the global alias still tees (back-compat), hence accumulates
    assert sum(TRACE.counts.values()) >= sum(global_before.values()) + \
        2 * sum(snaps[0]["counts"].values())


# ---------------------------------------------------------------------------
# health monitoring: NaN injection, thresholds, overflow routing
# ---------------------------------------------------------------------------

def test_nan_injection_raises_health_error_with_checkpoint_flat():
    """A schedule that goes NaN mid-run trips the non-finite guard at the
    next chunk boundary; the error names the last-good checkpoint and
    restoring it resumes a finite trajectory."""
    with tempfile.TemporaryDirectory() as d:
        runlog = os.path.join(d, "run.jsonl")
        eng = _engine(field=_nan_after())
        with pytest.raises(HealthError) as ei:
            eng.run(20, jax.random.PRNGKey(1), chunk=10, checkpoint_dir=d,
                    telemetry=Telemetry(runlog=runlog))
        err = ei.value
        assert err.chunk_index == 1, err.chunk_index
        assert err.signals["nonfinite"] > 0, err.signals
        assert err.checkpoint_path is not None
        assert os.path.exists(err.checkpoint_path), err.checkpoint_path
        assert "last-good checkpoint" in str(err)

        # the failed run's runlog records the failure (flight recorder)
        events = read_runlog(runlog)
        assert events[-1]["event"] == "run_end"
        assert events[-1]["status"] == "failed"
        recs = [e for e in events if e["event"] == "chunk"]
        assert recs[-1]["verdict"] == "fail"
        assert "error" in recs[-1]

        # abort-and-resume: a clean engine restores the checkpoint
        clean = _engine(field=jnp.asarray([0.0, 0.0, 5.0]))
        key = clean.restore(d)
        clean.run(10, key, chunk=10)
        assert np.isfinite(np.asarray(clean.state.pos)).all()
        assert np.isfinite(np.asarray(clean.state.spin)).all()
    # the partial trace (chunks up to the abort) kept its health rows
    assert eng.trace.health is not None
    assert eng.trace.health["nonfinite"].shape == (2,)
    assert eng.trace.health["nonfinite"][-1] > 0


def test_clean_run_passes_thresholds():
    """An NVE run passes tight drift/spin-norm thresholds over 2 chunks,
    health signals land in EngineTrace.health, verdicts in the runlog."""
    with tempfile.TemporaryDirectory() as d:
        runlog = os.path.join(d, "run.jsonl")
        eng = _engine()  # temperature=None -> NVE
        eng.run(20, jax.random.PRNGKey(4), chunk=10,
                telemetry=Telemetry(
                    runlog=runlog,
                    health=HealthConfig(max_energy_drift=0.2,
                                        max_spin_dev=1e-3)))
        h = eng.trace.health
        assert set(h) >= {"e_drift", "spin_dev", "nonfinite", "nbr_occ"}
        assert all(v.shape == (2,) for v in h.values())
        assert h["nonfinite"].sum() == 0
        assert np.abs(h["e_drift"]).max() < 0.2
        assert h["spin_dev"].max() < 1e-3
        events = read_runlog(runlog)
        recs = [e for e in events if e["event"] == "chunk"]
        assert [r["verdict"] for r in recs] == ["ok", "ok"]
        assert all("e_drift" in r["health"] for r in recs)
        assert events[-1]["status"] == "ok"
        assert events[-1]["metrics"]["counters"]["steps"] == 20


def test_threshold_violation_is_structured():
    """An absurdly tight drift threshold fails with the offending chunk
    and signal values attached (thermostatted run so drift is nonzero)."""
    eng = _engine(temperature=300.0)
    with pytest.raises(HealthError) as ei:
        eng.run(10, jax.random.PRNGKey(5), chunk=10,
                telemetry=Telemetry(
                    health=HealthConfig(max_energy_drift=1e-12)))
    err = ei.value
    assert err.chunk_index == 0
    assert "energy drift" in str(err)
    assert math.isfinite(err.signals["e_drift"])
    assert err.checkpoint_path is None  # run was not checkpointing


def test_migration_overflow_routes_health_error():
    """The PR-4 overflow raise now reports per-device drop counts, the
    offending chunk, and the last-good checkpoint via HealthError."""
    eng = _engine(plan=Sharded(), seed=5)
    eng.run(10, jax.random.PRNGKey(1), chunk=10)
    eng._carry = eng._carry._replace(
        n_dropped=jnp.asarray([3], jnp.int32))
    eng._last_ckpt = "/tmp/fake-ckpt"
    with pytest.raises(HealthError) as ei:
        eng._check_dropped(chunk_index=4)
    err = ei.value
    assert isinstance(err, RuntimeError)  # pre-telemetry catch keeps working
    assert "overflow" in str(err)
    assert err.chunk_index == 4
    assert err.signals["dropped"] == 3
    assert err.signals["dropped_per_device"] == {0: 3}
    assert err.checkpoint_path == "/tmp/fake-ckpt"


# ---------------------------------------------------------------------------
# runlog + report
# ---------------------------------------------------------------------------

def test_runlog_schema_and_report_renders():
    with tempfile.TemporaryDirectory() as d:
        runlog = os.path.join(d, "run.jsonl")
        eng = _engine()
        eng.run(20, jax.random.PRNGKey(6), chunk=10, telemetry=runlog)
        events = read_runlog(runlog)
        assert [e["event"] for e in events] == \
            ["run_start", "chunk", "chunk", "run_end"]
        start = events[0]
        assert start["schema"] == 1
        assert start["plan"] == "SingleDevice"
        assert start["provenance"]["jax_version"] == jax.__version__
        for rec in events[1:3]:
            assert {"steps", "steps_per_s", "wall_s", "compiles", "halo",
                    "health", "verdict", "chunk_cache"} <= set(rec)
        assert events[1]["compiles"] >= 1      # warmup chunk compiles
        assert events[2]["compiles"] == 0      # steady state does not

        from repro.launch.report import runlog_report
        text = runlog_report(runlog)
        assert "Run report" in text
        assert "steps/s" in text
        assert "health" in text


def test_telemetry_requires_fused_path():
    from repro.md.simulate import Simulation

    lat = simple_cubic()
    st = init_state(lat, (4, 4, 4), temperature=300.0, spin_init="helix_x",
                    key=jax.random.PRNGKey(0))
    sim = Simulation(potential=HeisenbergDMIModel(d0=0.008),
                     cfg=IntegratorConfig(dt=2e-3), state=st,
                     masses=jnp.asarray(lat.masses),
                     magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0,
                     capacity=8, skin=0.2, fused=False)
    with pytest.raises(ValueError, match="fused"):
        sim.run(10, jax.random.PRNGKey(1), chunk=10, telemetry="x.jsonl")


def test_bad_telemetry_type_rejected():
    eng = _engine()
    with pytest.raises(TypeError, match="telemetry"):
        eng.run(10, jax.random.PRNGKey(1), chunk=10, telemetry=42)


# ---------------------------------------------------------------------------
# 2-device sharded plan: NaN abort-and-resume + compile watchdog
# ---------------------------------------------------------------------------

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json, os.path, tempfile
import jax
import jax.numpy as jnp
import numpy as np
from repro.core.hamiltonian import HeisenbergDMIModel
from repro.ensemble import protocol
from repro.md.engine import Engine
from repro.md.integrator import IntegratorConfig
from repro.md.lattice import simple_cubic
from repro.md.state import init_state
from repro.parallel.plan import Sharded
from repro.telemetry import HealthConfig, Telemetry
from repro.telemetry.monitor import HealthError
from repro.telemetry.runlog import read_runlog

lat = simple_cubic()

def mk(field=None, temp=None):
    st = init_state(lat, (8, 6, 6), temperature=300.0, spin_init="helix_x",
                    key=jax.random.PRNGKey(0))
    return Engine(potential=HeisenbergDMIModel(d0=0.008),
                  cfg=IntegratorConfig(dt=2e-3, spin_alpha=0.05,
                                       lattice_gamma=1.0),
                  state=st, masses=jnp.asarray(lat.masses),
                  magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0,
                  capacity=16, skin=0.2, plan=Sharded(), temperature=temp,
                  field=field, observables=("energy", "magnetization"))

out = {}

# ---- NaN injection on the sharded plan: abort-and-resume ------------------
nan3 = [float("nan")] * 3
hold = [0.0, 0.0, 5.0]
nanf = protocol.piecewise([0.0, 0.021, 0.021, 1.0],
                          [hold, hold, nan3, nan3])
with tempfile.TemporaryDirectory() as d:
    runlog = os.path.join(d, "run.jsonl")
    eng = mk(field=nanf)
    err = None
    try:
        eng.run(20, jax.random.PRNGKey(1), chunk=10, checkpoint_dir=d,
                telemetry=Telemetry(runlog=runlog))
    except HealthError as e:
        err = e
    events = read_runlog(runlog)
    clean = mk(field=jnp.asarray(hold))
    key = clean.restore(d)
    clean.run(10, key, chunk=10)
    out["nan"] = {
        "raised": err is not None,
        "chunk_index": getattr(err, "chunk_index", None),
        "nonfinite": int(err.signals.get("nonfinite", 0)) if err else 0,
        "ckpt_exists": bool(err is not None and err.checkpoint_path
                            and os.path.exists(err.checkpoint_path)),
        "runlog_status": events[-1].get("status"),
        "resumed_finite": bool(
            np.isfinite(np.asarray(clean.state.pos)).all()
            and np.isfinite(np.asarray(clean.state.spin)).all()),
    }

# ---- compile watchdog: 0 recompiles across a schedule-driven run ----------
temp, field = protocol.field_cooling(300.0, 50.0, 25.0, t_hold=0.004,
                                     t_ramp=0.02)
with tempfile.TemporaryDirectory() as d:
    runlog = os.path.join(d, "run.jsonl")
    eng = mk(field=field, temp=temp)
    eng.run(40, jax.random.PRNGKey(2), chunk=10,
            telemetry=Telemetry(runlog=runlog,
                                health=HealthConfig(max_spin_dev=1e-3)))
    events = read_runlog(runlog)
    recs = [e for e in events if e.get("event") == "chunk"]
    ledger = eng.halo_ledger.snapshot()
    out["watchdog"] = {
        "n_chunks": len(recs),
        "warmup_compiles": recs[0]["compiles"],
        "steady_compiles": sum(r["compiles"] for r in recs[1:]),
        "verdicts": sorted({r["verdict"] for r in recs}),
        "halo_matches_ledger": all(r["halo"] == ledger for r in recs),
        "bytes_per_step": ledger["bytes_per_step"],
        "status": events[-1]["status"],
    }
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1800,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_nan_injection_sharded_abort_and_resume(sharded_result):
    res = sharded_result["nan"]
    assert res["raised"], res
    assert res["chunk_index"] == 1, res
    assert res["nonfinite"] > 0, res
    assert res["ckpt_exists"], res
    assert res["runlog_status"] == "failed", res
    assert res["resumed_finite"], res


def test_zero_recompiles_schedule_driven_sharded(sharded_result):
    """The compile watchdog across 4 schedule-driven sharded chunks: the
    warmup chunk compiles, every later chunk compiles NOTHING (knot values
    are runtime data), and every chunk record's halo field equals the
    run-scoped ledger snapshot."""
    res = sharded_result["watchdog"]
    assert res["n_chunks"] == 4, res
    assert res["warmup_compiles"] >= 1, res
    assert res["steady_compiles"] == 0, res
    assert res["verdicts"] == ["ok"], res
    assert res["halo_matches_ledger"], res
    assert res["bytes_per_step"] > 0, res
    assert res["status"] == "ok", res
