"""NEP-SPIN descriptor invariance + streaming-accumulation tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.descriptor import (NEPSpinSpec, descriptors,
                                   init_accumulators, accumulate, finalize,
                                   cutoff_fn, chebyshev_basis)
from repro.md.neighbor import dense_neighbor_table, gather_neighbors


def _setup(key, n=40, box_l=14.0, spec=None):
    spec = spec or NEPSpinSpec(l_max=3, n_ang=2, n_rad=3, n_spin=2,
                               basis_size=5)
    k1, k2, k3 = jax.random.split(key, 3)
    pos = jax.random.uniform(k1, (n, 3)) * box_l
    spin = jax.random.normal(k2, (n, 3))
    spin = spin / jnp.linalg.norm(spin, axis=-1, keepdims=True)
    types = (jax.random.uniform(k3, (n,)) < 0.5).astype(jnp.int32)
    box = jnp.full((3,), box_l)
    return spec, pos, spin, types, box


def _q(spec, params_desc, pos, spin, types, box, capacity=24):
    tab = dense_neighbor_table(pos, box, spec.cutoff, capacity)
    dr, dist, sj, tj, mask = gather_neighbors(pos, spin, types, tab, box)
    return descriptors(spec, params_desc, dr, dist, mask, types, tj, spin,
                       sj)


@pytest.fixture(scope="module")
def dp():
    from repro.core.potential import init_params
    spec = NEPSpinSpec(l_max=3, n_ang=2, n_rad=3, n_spin=2, basis_size=5)
    return spec, init_params(spec, jax.random.PRNGKey(7)).desc_params()


def test_translation_invariance(dp):
    spec, params = dp
    _, pos, spin, types, box = _setup(jax.random.PRNGKey(0), spec=spec)
    q1 = _q(spec, params, pos, spin, types, box)
    q2 = _q(spec, params, (pos + 3.123) % box, spin, types, box)
    np.testing.assert_allclose(np.sort(np.asarray(q1), axis=0),
                               np.sort(np.asarray(q2), axis=0), rtol=1e-4,
                               atol=1e-5)


def test_joint_rotation_invariance(dp):
    """Descriptor must be invariant under JOINT SO(3) rotation of lattice
    and spins (the symmetry of spin-orbit-coupled magnets)."""
    spec, params = dp
    _, pos, spin, types, box = _setup(jax.random.PRNGKey(1), spec=spec)
    # rotate positions about box center + spins with the same matrix
    th = 0.73
    R = jnp.asarray([[np.cos(th), -np.sin(th), 0],
                     [np.sin(th), np.cos(th), 0],
                     [0, 0, 1.0]])
    tab = dense_neighbor_table(pos, box, spec.cutoff, 24)
    dr, dist, sj, tj, mask = gather_neighbors(pos, spin, types, tab, box)
    q1 = descriptors(spec, params, dr, dist, mask, types, tj, spin, sj)
    # rotate the gathered geometry directly (avoids PBC-box-shape issues)
    q2 = descriptors(spec, params, dr @ R.T, dist, mask, types, tj,
                     spin @ R.T, sj @ R.T)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-4,
                               atol=1e-5)


def test_spin_rotation_alone_changes_descriptor(dp):
    """Rotating spins WITHOUT the lattice must change the DMI-carrier
    channels (spin-orbit coupling breaks pure-spin rotation symmetry)."""
    spec, params = dp
    _, pos, spin, types, box = _setup(jax.random.PRNGKey(2), spec=spec)
    th = 1.1
    R = jnp.asarray([[np.cos(th), -np.sin(th), 0],
                     [np.sin(th), np.cos(th), 0],
                     [0, 0, 1.0]])
    q1 = _q(spec, params, pos, spin, types, box)
    q2 = _q(spec, params, pos, spin @ R.T, types, box)
    assert float(jnp.abs(q1 - q2).max()) > 1e-6


def test_streaming_accumulation_equivalence(dp):
    """Splitting the neighbor list into blocks and streaming through
    accumulate() must match the one-shot descriptor (the property the
    27-stencil domain path and the Pallas kernels rely on)."""
    spec, params = dp
    _, pos, spin, types, box = _setup(jax.random.PRNGKey(3), spec=spec)
    tab = dense_neighbor_table(pos, box, spec.cutoff, 24)
    dr, dist, sj, tj, mask = gather_neighbors(pos, spin, types, tab, box)
    q1 = descriptors(spec, params, dr, dist, mask, types, tj, spin, sj)

    acc = init_accumulators(spec, (pos.shape[0],), pos.dtype)
    for sl in (slice(0, 7), slice(7, 16), slice(16, 24)):
        acc = accumulate(spec, params, acc, dr[:, sl], dist[:, sl],
                         mask[:, sl], types, tj[:, sl], spin, sj[:, sl])
    q2 = finalize(spec, acc, spin)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-5,
                               atol=1e-6)


def test_cutoff_smoothness():
    r = jnp.linspace(0.0, 5.0, 101)
    fc = cutoff_fn(r, 5.0)
    assert float(fc[0]) == 1.0
    assert abs(float(fc[-1])) < 1e-12
    # derivative -> 0 at the cutoff
    g = jax.vmap(jax.grad(lambda x: cutoff_fn(x, 5.0)))(r)
    assert abs(float(g[-1])) < 1e-6


def test_chebyshev_basis_range():
    r = jnp.linspace(0.1, 4.9, 37)
    fk = chebyshev_basis(r, 5.0, 8)
    assert fk.shape == (37, 8)
    assert float(jnp.abs(fk).max()) <= 1.0 + 1e-6


def test_permutation_invariance(dp):
    """Neighbor-order permutation must not change the descriptor."""
    spec, params = dp
    _, pos, spin, types, box = _setup(jax.random.PRNGKey(4), spec=spec)
    tab = dense_neighbor_table(pos, box, spec.cutoff, 24)
    dr, dist, sj, tj, mask = gather_neighbors(pos, spin, types, tab, box)
    q1 = descriptors(spec, params, dr, dist, mask, types, tj, spin, sj)
    perm = jax.random.permutation(jax.random.PRNGKey(5), dr.shape[1])
    q2 = descriptors(spec, params, dr[:, perm], dist[:, perm],
                     mask[:, perm], types, tj[:, perm], spin, sj[:, perm])
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-5,
                               atol=1e-6)
