"""Fault-tolerant supervision acceptance (PR 7).

The recovery contracts, each asserted here:

* seeded fault injection (NaN, SDC bit-flip) on the flat plan is caught
  by the PR-6 health gate and the supervisor's rollback-retry reproduces
  the uninterrupted trajectory BITWISE within 2 retries;
* a plain retry reuses the already-compiled chunk: every runlog chunk
  record logged after the first rollback shows 0 compiles;
* two consecutive same-class transient failures climb the dt degradation
  ladder (halve dt for a span, then restore), and the engine comes back
  at the original dt;
* on a 2-simulated-device sharded plan (subprocess, x64): NaN recovery
  is bitwise with 0 retry recompiles, a persistent per-device migration
  overflow climbs the capacity ladder (rebind at 2x cell capacity), and
  a corrupted-halo fault recovers bitwise;
* elastic restart: a 2-device ``DomainCarry`` checkpoint restores onto a
  1-device mesh (and back up), with f64 energy parity to a same-mesh
  restore through the same gather + re-bin + rebuild path - the in-scan
  carry ff lags the final spin state by O(dt), so parity is defined
  against a same-mesh restore that also rebuilds, not the live engine;
* host crash (SIGKILL mid-run, subprocess): at most one chunk of work is
  lost and resume from the newest checkpoint is bitwise.
"""
import json
import os
import signal
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hamiltonian import HeisenbergDMIModel
from repro.md.engine import Engine
from repro.md.integrator import IntegratorConfig
from repro.md.lattice import simple_cubic
from repro.md.state import init_state
from repro.resilience import (Fault, FaultPlan, Supervisor, SupervisorConfig,
                              install_faults)
from repro.telemetry import HealthConfig, HealthError, Telemetry, read_runlog

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _make_engine():
    lat = simple_cubic()
    st = init_state(lat, (4, 4, 4), temperature=300.0, spin_init="helix_x",
                    key=jax.random.PRNGKey(3))
    return Engine(potential=HeisenbergDMIModel(d0=0.008),
                  cfg=IntegratorConfig(dt=2e-3, spin_alpha=0.05,
                                       lattice_gamma=1.0),
                  state=st, masses=jnp.asarray(lat.masses),
                  magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0,
                  capacity=8, skin=0.2,
                  observables=("energy", "magnetization"))


# ---------------------------------------------------------------------------
# flat plan, in-process
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def flat_recovery(tmp_path_factory):
    """One clean run + one supervised NaN-faulted run, shared across the
    flat-plan assertions (compiling the chunk twice is the whole cost)."""
    tmp = tmp_path_factory.mktemp("resil")
    log = str(tmp / "run.jsonl")
    key = jax.random.PRNGKey(0)
    ref = _make_engine()
    ref.run(40, key, chunk=10)

    eng = _make_engine()
    inj = install_faults(eng, FaultPlan(faults=(
        Fault(kind="nan", step=25, leaf="force"),)), runlog=log)
    sup = Supervisor(SupervisorConfig(max_retries=2))
    out = sup.run(eng, 40, key, chunk=10, checkpoint_dir=str(tmp / "ck"),
                  telemetry=Telemetry(runlog=log, health=HealthConfig()))
    return {"ref": ref.state, "out": out, "sup": sup, "inj": inj,
            "log": log}


def test_supervised_nan_recovery_bitwise(flat_recovery):
    """An injected NaN is rolled back and retried; the recovered
    trajectory is bitwise identical to the uninterrupted run."""
    r = flat_recovery
    assert [e["event"] for e in r["sup"].events] == \
        ["rollback", "retry", "recovered"]
    assert r["sup"].events[-1]["attempts"] <= 2
    for leaf in ("pos", "vel", "spin"):
        a = np.asarray(getattr(r["ref"], leaf))
        b = np.asarray(getattr(r["out"], leaf))
        assert np.array_equal(a, b), f"{leaf}: max {np.abs(a - b).max()}"


def test_recovery_events_in_runlog(flat_recovery):
    """Every recovery action lands in the telemetry runlog as a structured
    record, and launch/report.py renders them."""
    events = [rec["event"] for rec in read_runlog(flat_recovery["log"])]
    for ev in ("fault_injected", "rollback", "retry", "recovered"):
        assert ev in events, events
    from repro.launch.report import runlog_report
    text = runlog_report(flat_recovery["log"])
    assert "rollback" in text and "recovered" in text


def test_report_renders_every_resilience_event(tmp_path):
    """launch/report.py has a render line for each structured resilience
    record the supervisor / injector can emit."""
    from repro.launch.report import runlog_report
    from repro.telemetry.runlog import append_event
    log = str(tmp_path / "r.jsonl")
    append_event(log, "run_start", schema=1, plan="sharded")
    append_event(log, "fault_injected", kind="nan", fault_step=5,
                 chunk_step=0, leaf="spin", device=0)
    append_event(log, "rollback", kind="nonfinite", attempt=1, step=10,
                 chunk_index=0, signals={}, checkpoint="ck", error="x")
    append_event(log, "degrade", kind="overflow", action="capacity",
                 cell_capacity=32, prev_capacity=16, step=10)
    append_event(log, "degrade", kind="nonfinite", action="dt", dt=1e-3,
                 prev_dt=2e-3, span_steps=20, step=10)
    append_event(log, "degrade_restore", kind="nonfinite", dt=2e-3, step=30)
    append_event(log, "retry", attempt=1, kind="nonfinite", step=10,
                 remaining=30)
    append_event(log, "elastic_restore", step=20,
                 from_layout={"devices": 2, "cells": [4, 2, 2],
                              "cell_capacity": 16},
                 to_layout={"devices": 1, "cells": [2, 2, 2],
                            "cell_capacity": 32}, checkpoint="ck")
    append_event(log, "recovered", attempts=2, step=40)
    append_event(log, "give_up", kind="nonfinite", attempts=5, step=10)
    text = runlog_report(log)
    for token in ("fault_injected: nan", "rollback #1", "retry #1",
                  "cell_capacity 16 -> 32", "dt 0.002 -> 0.001",
                  "degrade_restore", "elastic_restore at step 20",
                  "2 -> 1 device", "recovered after 2",
                  "give_up: nonfinite"):
        assert token in text, (token, text)


def test_zero_recompile_retry(flat_recovery):
    """A rollback-retry with unchanged config reuses the compiled chunk:
    every chunk record after the first rollback shows 0 compiles."""
    records = read_runlog(flat_recovery["log"])
    first_rb = next(i for i, rec in enumerate(records)
                    if rec["event"] == "rollback")
    after = [rec["compiles"] for rec in records[first_rb:]
             if rec["event"] == "chunk"]
    assert after, "no chunk records after the rollback"
    assert all(c == 0 for c in after), after


def test_bit_flip_recovery_bitwise(tmp_path):
    """A silent-data-corruption bit flip (top exponent bit of one spin
    component) is detected and recovered bitwise."""
    key = jax.random.PRNGKey(0)
    ref = _make_engine()
    ref.run(40, key, chunk=10)
    eng = _make_engine()
    install_faults(eng, FaultPlan(faults=(
        Fault(kind="bit_flip", step=15, leaf="spin", bit=30),)))
    sup = Supervisor(SupervisorConfig(max_retries=2))
    out = sup.run(eng, 40, key, chunk=10, checkpoint_dir=str(tmp_path),
                  telemetry=Telemetry(health=HealthConfig()))
    assert [e["event"] for e in sup.events] == \
        ["rollback", "retry", "recovered"]
    for leaf in ("pos", "vel", "spin"):
        assert np.array_equal(np.asarray(getattr(ref.state, leaf)),
                              np.asarray(getattr(out, leaf))), leaf


def test_dt_degradation_ladder(tmp_path):
    """Two consecutive same-class failures trigger the dt ladder: run a
    span at dt/2 through the trouble spot, then restore full dt.  The
    fault models a dt-fixable instability (inert below its threshold)."""
    eng = _make_engine()
    inj = install_faults(eng, FaultPlan(faults=(
        Fault(kind="nan", step=25, leaf="spin", once=False,
              while_dt_ge=2e-3),)))
    sup = Supervisor(SupervisorConfig(max_retries=4, degrade_after=2))
    out = sup.run(eng, 40, jax.random.PRNGKey(0), chunk=10,
                  checkpoint_dir=str(tmp_path),
                  telemetry=Telemetry(health=HealthConfig()))
    evs = [e["event"] for e in sup.events]
    assert evs == ["rollback", "retry", "rollback", "degrade",
                   "degrade_restore", "retry", "recovered"], evs
    degrade = next(e for e in sup.events if e["event"] == "degrade")
    assert degrade["action"] == "dt"
    assert degrade["dt"] == pytest.approx(1e-3)
    assert float(eng.cfg.dt) == pytest.approx(2e-3)   # restored
    assert eng._step_now() == 40
    assert np.isfinite(np.asarray(out.spin)).all()
    assert len(inj.fired) == 2   # inert once dt dropped


def test_give_up_reraises(tmp_path):
    """Past the retry budget the supervisor re-raises the HealthError and
    logs a give_up event."""
    eng = _make_engine()
    install_faults(eng, FaultPlan(faults=(
        Fault(kind="nan", step=5, leaf="force", once=False),)))
    sup = Supervisor(SupervisorConfig(max_retries=0))
    with pytest.raises(HealthError):
        sup.run(eng, 20, jax.random.PRNGKey(0), chunk=10,
                checkpoint_dir=str(tmp_path),
                telemetry=Telemetry(health=HealthConfig()))
    assert [e["event"] for e in sup.events] == ["rollback", "give_up"]


def test_fault_validation():
    with pytest.raises(ValueError, match="kind"):
        Fault(kind="gremlin", step=0)
    with pytest.raises(ValueError, match="leaf"):
        Fault(kind="nan", step=0, leaf="mass")
    # overflow / halo target per-device state: flat plan rejects at install
    eng = _make_engine()
    for kind in ("overflow", "halo"):
        with pytest.raises(ValueError, match="sharded"):
            install_faults(eng, FaultPlan(faults=(Fault(kind=kind, step=0),)))


# ---------------------------------------------------------------------------
# sharded plan + elastic restart, subprocess (2 forced host devices, x64)
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
jax.config.update("jax_enable_x64", True)
import json, tempfile
import numpy as np
import jax.numpy as jnp
from repro.core.hamiltonian import HeisenbergDMIModel
from repro.md.engine import Engine
from repro.md.integrator import IntegratorConfig
from repro.md.lattice import simple_cubic
from repro.md.state import init_state
from repro.parallel.plan import Sharded
from repro.resilience import (Fault, FaultPlan, Supervisor, SupervisorConfig,
                              install_faults)
from repro.telemetry import HealthConfig, Telemetry, read_runlog


def make_engine(plan):
    lat = simple_cubic()
    st = init_state(lat, (6, 6, 6), temperature=300.0, spin_init="helix_x",
                    key=jax.random.PRNGKey(3))
    return Engine(potential=HeisenbergDMIModel(d0=0.008),
                  cfg=IntegratorConfig(dt=2e-3, spin_alpha=0.05,
                                       lattice_gamma=1.0),
                  state=st, masses=jnp.asarray(lat.masses),
                  magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0,
                  capacity=16, skin=0.2, plan=plan,
                  observables=("energy", "magnetization"))


tmp = tempfile.mkdtemp()
key = jax.random.PRNGKey(0)
out = {}

# 1. sharded NaN recovery: bitwise + zero retry recompiles
ref = make_engine(Sharded())
ref.run(40, key, chunk=10)
eng = make_engine(Sharded())
log = os.path.join(tmp, "s.jsonl")
install_faults(eng, FaultPlan(faults=(
    Fault(kind="nan", step=25, leaf="spin"),)), runlog=log)
sup = Supervisor(SupervisorConfig(max_retries=2))
st = sup.run(eng, 40, key, chunk=10,
             checkpoint_dir=os.path.join(tmp, "ck1"),
             telemetry=Telemetry(runlog=log, health=HealthConfig()))
recs = read_runlog(log)
first_rb = next(i for i, r in enumerate(recs) if r["event"] == "rollback")
out["nan"] = {
    "events": [e["event"] for e in sup.events],
    "bitwise": all(np.array_equal(np.asarray(getattr(ref.state, l)),
                                  np.asarray(getattr(st, l)))
                   for l in ("pos", "vel", "spin")),
    "retry_compiles": [r["compiles"] for r in recs[first_rb:]
                       if r["event"] == "chunk"],
}

# 2. persistent per-device overflow -> capacity ladder
eng2 = make_engine(Sharded())
cap0 = int(eng2._rplan.dspec.capacity)
install_faults(eng2, FaultPlan(faults=(
    Fault(kind="overflow", step=15, device=1, once=False),)))
sup2 = Supervisor(SupervisorConfig(max_retries=4, degrade_after=2))
sup2.run(eng2, 40, key, chunk=10, checkpoint_dir=os.path.join(tmp, "ck2"))
out["overflow"] = {
    "events": [e["event"] for e in sup2.events],
    "cap0": cap0, "cap1": int(eng2._rplan.dspec.capacity),
    "final_step": int(eng2._step_now()),
}

# 3. corrupted-halo fault on one device
eng3 = make_engine(Sharded())
install_faults(eng3, FaultPlan(faults=(
    Fault(kind="halo", step=15, device=1),)))
sup3 = Supervisor(SupervisorConfig(max_retries=2))
st3 = sup3.run(eng3, 40, key, chunk=10,
               checkpoint_dir=os.path.join(tmp, "ck3"),
               telemetry=Telemetry(health=HealthConfig()))
out["halo"] = {
    "events": [e["event"] for e in sup3.events],
    "bitwise": all(np.array_equal(np.asarray(getattr(ref.state, l)),
                                  np.asarray(getattr(st3, l)))
                   for l in ("pos", "spin")),
}

# 4. elastic restart 2 -> 1 -> 2.  The in-scan carry ff lags the final
# spin state by O(dt), so energy parity is defined against a SAME-MESH
# restore through the same gather + re-bin + rebuild path.
eng4 = make_engine(Sharded())
ck = os.path.join(tmp, "ck4")
eng4.run(20, key, chunk=10, checkpoint_dir=ck)
e_live = float(np.asarray(eng4.energy))

eng4b = make_engine(Sharded())          # same-mesh restore THROUGH rebuild
key4b = eng4b.restore(ck, plan=Sharded())
e_same = float(np.asarray(eng4b.energy))

eng5 = make_engine(Sharded())           # 2 -> 1 device
sup5 = Supervisor(runlog=os.path.join(tmp, "e.jsonl"))
key5 = sup5.elastic_restore(eng5, ck,
                            Sharded(devices=tuple(jax.devices()[:1])))
e_down = float(np.asarray(eng5.energy))

eng4b.run(20, key4b, chunk=10)          # continue both sides 20 steps
eng5.run(20, key5, chunk=10)
e_same_end = float(np.asarray(eng4b.energy))
e_down_end = float(np.asarray(eng5.energy))

ck5 = os.path.join(tmp, "ck5")          # 1 -> 2 device, vs 1 -> 1
eng5.save(ck5, key=jax.random.PRNGKey(7))
eng6 = make_engine(Sharded(devices=tuple(jax.devices()[:1])))
eng6.restore(ck5, plan=Sharded())
eng7 = make_engine(Sharded(devices=tuple(jax.devices()[:1])))
eng7.restore(ck5, plan=Sharded(devices=tuple(jax.devices()[:1])))
out["elastic"] = {
    "mesh_down": int(eng5._rplan.mesh.size),
    "mesh_up": int(eng6._rplan.mesh.size),
    "lag": abs(e_same - e_live),
    "down_delta": abs(e_down - e_same),
    "down_end_delta": abs(e_down_end - e_same_end),
    "up_delta": abs(float(np.asarray(eng6.energy))
                    - float(np.asarray(eng7.energy))),
    "events": [e["event"] for e in sup5.events],
}
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1800,
                       cwd=_REPO)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_sharded_nan_recovery(sharded_result):
    res = sharded_result["nan"]
    assert res["events"] == ["rollback", "retry", "recovered"]
    assert res["bitwise"]
    assert res["retry_compiles"] and \
        all(c == 0 for c in res["retry_compiles"]), res["retry_compiles"]


def test_overflow_capacity_ladder(sharded_result):
    """A persistent migration overflow on one device climbs the capacity
    ladder: rebind with 2x cell capacity, then the run completes."""
    res = sharded_result["overflow"]
    assert "degrade" in res["events"], res["events"]
    assert res["cap1"] >= 2 * res["cap0"], res
    assert res["final_step"] == 40


def test_halo_fault_recovery(sharded_result):
    res = sharded_result["halo"]
    assert res["events"] == ["rollback", "retry", "recovered"]
    assert res["bitwise"]


def test_elastic_restart_parity(sharded_result):
    """2-dev -> 1-dev restore matches a same-mesh restore through the
    same migration rebuild at f64; scaling back up matches too."""
    res = sharded_result["elastic"]
    assert res["mesh_down"] == 1 and res["mesh_up"] == 2
    assert res["lag"] < 1e-4            # in-scan ff lags by O(dt) only
    assert res["down_delta"] < 1e-10, res
    assert res["down_end_delta"] < 1e-8, res
    assert res["up_delta"] < 1e-10, res
    assert "elastic_restore" in res["events"]


# ---------------------------------------------------------------------------
# host crash: SIGKILL mid-run, resume from newest checkpoint (subprocess)
# ---------------------------------------------------------------------------

_CRASH_COMMON = r"""
import os, sys
import jax
import jax.numpy as jnp
import numpy as np
from repro.core.hamiltonian import HeisenbergDMIModel
from repro.md.engine import Engine
from repro.md.integrator import IntegratorConfig
from repro.md.lattice import simple_cubic
from repro.md.state import init_state


def make_engine():
    lat = simple_cubic()
    st = init_state(lat, (4, 4, 4), temperature=300.0, spin_init="helix_x",
                    key=jax.random.PRNGKey(3))
    return Engine(potential=HeisenbergDMIModel(d0=0.008),
                  cfg=IntegratorConfig(dt=2e-3, spin_alpha=0.05,
                                       lattice_gamma=1.0),
                  state=st, masses=jnp.asarray(lat.masses),
                  magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0,
                  capacity=8, skin=0.2)
"""

_CRASH_SCRIPT = _CRASH_COMMON + r"""
from repro.resilience import Fault, FaultPlan, install_faults
eng = make_engine()
install_faults(eng, FaultPlan(faults=(Fault(kind="crash", step=25),)))
eng.run(40, jax.random.PRNGKey(0), chunk=10,
        checkpoint_dir=sys.argv[1], checkpoint_every=1)
print("UNREACHABLE")
"""

_RESUME_SCRIPT = _CRASH_COMMON + r"""
import json
from repro.ckpt.checkpoint import latest_step
ck = sys.argv[1]
ref = make_engine()
ref.run(40, jax.random.PRNGKey(0), chunk=10)
eng = make_engine()
key = eng.restore(ck)
start = int(eng._step_now())
eng.run(40 - start, key, chunk=10)
out = {
    "latest": latest_step(ck), "resumed_from": start,
    "bitwise": all(np.array_equal(np.asarray(getattr(ref.state, l)),
                                  np.asarray(getattr(eng.state, l)))
                   for l in ("pos", "vel", "spin")),
}
print("RESULT " + json.dumps(out))
"""


def test_sigkill_resume_bitwise(tmp_path):
    """A SIGKILLed run loses at most one chunk of work; resuming from the
    newest checkpoint reproduces the uninterrupted trajectory bitwise."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    ck = str(tmp_path / "ck")
    r = subprocess.run([sys.executable, "-c", _CRASH_SCRIPT, ck], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=_REPO)
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr[-2000:])
    assert "UNREACHABLE" not in r.stdout

    r2 = subprocess.run([sys.executable, "-c", _RESUME_SCRIPT, ck], env=env,
                        capture_output=True, text=True, timeout=900,
                        cwd=_REPO)
    assert r2.returncode == 0, r2.stderr[-3000:]
    line = [ln for ln in r2.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    res = json.loads(line[len("RESULT "):])
    # crash was injected at the [20, 30) chunk boundary: steps 0-20 are
    # checkpointed, at most one chunk (10 steps) of work is lost
    assert res["latest"] == 20, res
    assert 40 - res["resumed_from"] <= 20, res
    assert res["bitwise"], res
