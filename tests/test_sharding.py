"""Sharding-rule resolution tests (no devices needed - pure spec logic)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (LOGICAL, param_pspec, resolve_spec)


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_batch_axes_resolution():
    spec = resolve_spec(MESH2, ("batch", None, None), (256, 4096, 1024))
    assert spec == P(("pod", "data"), None, None)


def test_nondividing_axis_dropped():
    # kv_heads = 4 under model=16 -> replicated
    spec = resolve_spec(MESH1, ("embed", "kv_heads", None), (1024, 4, 128))
    assert spec == P(None, None, None)
    # kv_heads = 32 -> sharded
    spec = resolve_spec(MESH1, ("embed", "kv_heads", None), (1024, 32, 128))
    assert spec == P(None, "model", None)


def test_experts_2d_vs_1d():
    # 256 experts cover data x model -> 2-D sharding
    spec = resolve_spec(MESH1, ("experts", None, None), (256, 7168, 2048))
    assert spec == P(("data", "model"), None, None)
    # 64 experts -> prefix fallback to model only
    spec = resolve_spec(MESH1, ("experts", None, None), (64, 2048, 1408))
    assert spec == P("model", None, None)


def test_param_rules_match_paths():
    assert param_pspec(("g0", "attn", "wq"), 4) == \
        ("layers", "embed", "heads", None)
    assert param_pspec(("g1", "moe", "wi"), 4) == \
        ("layers", "experts", "embed", None)
    assert param_pspec(("embed",), 2) == ("vocab", "embed")
    assert param_pspec(("g0", "ssm", "in_proj"), 3) == \
        ("layers", "embed", "ffn")
    # unknown -> replicated
    assert param_pspec(("whatever",), 3) == (None, None, None)


def test_batch_smaller_than_axes_replicates():
    spec = resolve_spec(MESH2, ("batch",), (1,))   # long_500k B=1
    assert spec == P(None)


def test_vocab_padding_multiple():
    from repro.models.transformer import padded_vocab
    assert padded_vocab(50280) % 256 == 0
    assert padded_vocab(50280) >= 50280
    assert padded_vocab(152064) == 152064
