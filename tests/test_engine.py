"""Unified engine acceptance: schedules in-scan on every plan, declarative
observables, checkpoint-restart determinism, kernel-through-sharded.

The PR-5 acceptance tests:

* a time-varying field protocol evaluated INSIDE the compiled scan gives
  the same f64 trajectory on the flat and sharded plans (NVE: the
  schedule is the only time dependence), with ZERO recompiles across
  chunks on the sharded plan (knot values are runtime data);
* the in-scan observable pipeline reproduces ``md/analysis.py``
  (topological charge, pitch, magnetization) on both plans, including the
  psum-reduced grid accumulation of the sharded pipeline;
* checkpoint-restart at a chunk boundary (``ckpt.save_md``/``load_md``
  via ``Engine.save``/``restore``) resumes bitwise-identically on the
  flat, replica, and sharded plans;
* the fused NEP kernel evaluator (``use_kernel=True``, mode "auto" -
  the compiled xla_tiled executor on CPU) rides the sharded plan through
  the q_Fp adjoint-accumulator halo and tracks the flat kernel path;
* ``obs_every`` streams observables from inside the scan at the right
  times.
"""
import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hamiltonian import HeisenbergDMIModel
from repro.ensemble import protocol
from repro.md.analysis import helix_pitch, magnetization, topological_charge
from repro.md.engine import Engine
from repro.md.integrator import IntegratorConfig
from repro.md.lattice import simple_cubic
from repro.md.state import init_state
from repro.parallel.plan import Replicated

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
jax.config.update("jax_enable_x64", True)
import json, tempfile
import numpy as np
import jax.numpy as jnp
from repro.core.hamiltonian import HeisenbergDMIModel
from repro.ensemble import protocol
from repro.md.analysis import topological_charge
from repro.md.engine import Engine
from repro.md.integrator import IntegratorConfig
from repro.md.lattice import simple_cubic
from repro.md.state import init_state
from repro.parallel.plan import Sharded

compiles = {"n": 0}
def on_event(name, _d, **k):
    if name == "/jax/core/compile/backend_compile_duration":
        compiles["n"] += 1
jax.monitoring.register_event_duration_secs_listener(on_event)

lat = simple_cubic()
st = init_state(lat, (8, 8, 8), temperature=300.0, spin_init="helix_x",
                key=jax.random.PRNGKey(7))
kw = dict(cfg=IntegratorConfig(dt=2e-3), state=st,
          masses=jnp.asarray(lat.masses),
          magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0, capacity=32,
          skin=0.2)
ham = HeisenbergDMIModel(d0=0.008, ka=0.001)
out = {}

# ---- in-scan field schedule: flat vs sharded f64 parity (NVE) -------------
fld = protocol.piecewise([0.0, 0.04, 0.04, 0.12],
                         [[0.0, 0.0, 0.0], [0.0, 0.0, 30.0],
                          [0.0, 0.0, 30.0], [15.0, 0.0, 5.0]])
obs = ("energy", "kinetic", "magnetization", "charge")
flat = Engine(potential=ham, field=fld, observables=obs, **kw)
sh = Engine(potential=ham, field=fld, observables=obs, plan=Sharded(), **kw)
flat.run(50, jax.random.PRNGKey(1), chunk=10)
c0 = compiles["n"]
sh.run(50, jax.random.PRNGKey(1), chunk=10)   # same compiled chunk, 5 calls
out["sched"] = {
    "pos": float(jnp.abs(flat.state.pos - sh.state.pos).max()),
    "spin": float(jnp.abs(flat.state.spin - sh.state.spin).max()),
    "recompiles_after_first_chunk": 0,  # filled below
    "chunk_cache": len(sh._chunk_cache),
    "rebuilds": sh.n_rebuilds,
}
out["sched"]["charge_flat"] = [float(q) for q in
                               flat.trace.values["charge"]]
out["sched"]["charge_sharded"] = [float(q) for q in
                                  sh.trace.values["charge"]]
out["sched"]["charge_analysis"] = float(topological_charge(
    sh.state.pos, sh.state.spin, sh.state.box, grid=(32, 32)))
c1 = compiles["n"]
sh.run(50, jax.random.PRNGKey(2), chunk=10)   # protocol advances in-scan
out["sched"]["recompiles_after_first_chunk"] = compiles["n"] - c1

# ---- checkpoint-restart bitwise on the sharded plan -----------------------
cfgT = IntegratorConfig(dt=2e-3, spin_alpha=0.05, lattice_gamma=1.0)
kwT = dict(kw); kwT["cfg"] = cfgT
temp = protocol.linear(0.0, 0.1, 300.0, 50.0)
a = Engine(potential=ham, plan=Sharded(), temperature=temp, **kwT)
a.run(60, jax.random.PRNGKey(5), chunk=20)
with tempfile.TemporaryDirectory() as d:
    b = Engine(potential=ham, plan=Sharded(), temperature=temp, **kwT)
    b.run(40, jax.random.PRNGKey(5), chunk=20, checkpoint_dir=d)
    c = Engine(potential=ham, plan=Sharded(), temperature=temp, **kwT)
    key = c.restore(d)
    c.run(20, key, chunk=20)
out["ckpt"] = {
    "pos_bitwise": bool(jnp.all(a.state.pos == c.state.pos)),
    "spin_bitwise": bool(jnp.all(a.state.spin == c.state.spin)),
    "vel_bitwise": bool(jnp.all(a.state.vel == c.state.vel)),
    "rebuilds_match": a.n_rebuilds == c.n_rebuilds,
}

# ---- replica axis sharded over devices: parity + sharded restore ----------
from repro.parallel.plan import Replicated

str_ = init_state(lat, (4, 4, 4), temperature=400.0, spin_init="helix_x",
                  key=jax.random.PRNGKey(3))
kwr = dict(potential=ham, cfg=cfgT, state=str_,
           masses=jnp.asarray(lat.masses),
           magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0, capacity=8,
           skin=0.2, temperature=100.0)
u = Engine(plan=Replicated(2), **kwr)
u.run(40, jax.random.PRNGKey(5), chunk=20)
s2 = Engine(plan=Replicated(2, devices=tuple(jax.devices())), **kwr)
s2.run(40, jax.random.PRNGKey(5), chunk=20)
with tempfile.TemporaryDirectory() as d:
    b2 = Engine(plan=Replicated(2, devices=tuple(jax.devices())), **kwr)
    b2.run(20, jax.random.PRNGKey(5), chunk=20, checkpoint_dir=d)
    c2 = Engine(plan=Replicated(2, devices=tuple(jax.devices())), **kwr)
    k2 = c2.restore(d)
    sharded_restore = "replica" in str(c2._carry.states.pos.sharding.spec)
    c2.run(20, k2, chunk=20)
out["replica_shard"] = {
    "matches_unsharded": bool(jnp.all(s2.state.pos == u.state.pos)
                              & jnp.all(s2.state.spin == u.state.spin)),
    "restore_sharded": sharded_restore,
    "resume_bitwise": bool(jnp.all(s2.state.pos == c2.state.pos)
                           & jnp.all(s2.state.spin == c2.state.spin)),
}

# ---- Pallas NEP kernel through the sharded plan (q_Fp halo) ---------------
from repro.core.descriptor import NEPSpinSpec
from repro.core.potential import NEPSpinPotential, init_params
from repro.parallel.halo import TRACE

stk = init_state(lat, (8, 6, 6), temperature=300.0, spin_init="helix_x",
                 key=jax.random.PRNGKey(0), dtype=jnp.float32)
spec = NEPSpinSpec(l_max=2, n_ang=2, n_rad=4, n_spin=2, basis_size=6)
params = init_params(spec, jax.random.PRNGKey(0), dtype=jnp.float32)
pot = NEPSpinPotential(spec, params, use_kernel=True)
from repro.kernels.nep import resolve_mode
assert resolve_mode(pot.mode) == "xla_tiled"   # CPU backend dispatch
kwk = dict(cfg=IntegratorConfig(dt=2e-3), state=stk,
           masses=jnp.asarray(lat.masses, jnp.float32),
           magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0, capacity=16,
           skin=0.2, field=jnp.asarray([0.0, 0.0, 2.0]))
fk = Engine(potential=pot, **kwk)
TRACE.reset()
sk = Engine(potential=pot, plan=Sharded(), **kwk)
out["kernel"] = {
    # relative: the xla_tiled executor compiles distinct programs for the
    # flat vs per-device shapes, so total energies differ by O(ulp)*|E|
    "e0": abs(float(fk.energy) - float(sk.energy))
          / max(abs(float(fk.energy)), 1.0),
    "f0": float(jnp.abs(fk._ff.force - sk._ff.force).max()),
    "h0": float(jnp.abs(fk._ff.field - sk._ff.field).max()),
    "qfp_exchanges": TRACE.counts.get("qfp", 0),
}
fk.run(6, jax.random.PRNGKey(1), chunk=3)
sk.run(6, jax.random.PRNGKey(1), chunk=3)
out["kernel"].update({
    "pos": float(jnp.abs(fk.state.pos - sk.state.pos).max()),
    "spin": float(jnp.abs(fk.state.spin - sk.state.spin).max()),
})
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def engine_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1800,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_schedule_in_scan_sharded_parity(engine_result):
    """A time-varying field protocol evaluated inside the compiled scan
    drives flat and sharded plans to the same f64 trajectory."""
    res = engine_result["sched"]
    assert res["rebuilds"] >= 1, res
    assert res["pos"] < 1e-9, res
    assert res["spin"] < 1e-9, res


def test_schedule_in_scan_zero_recompiles(engine_result):
    """Field cooling on the sharded plan: one compiled chunk, 0 recompiles
    as the protocol advances across chunks."""
    res = engine_result["sched"]
    assert res["recompiles_after_first_chunk"] == 0, res
    assert res["chunk_cache"] == 1, res


def test_observable_pipeline_matches_analysis(engine_result):
    """The psum-reduced sharded charge pipeline reproduces md/analysis.py
    (and the flat pipeline, which calls it verbatim)."""
    res = engine_result["sched"]
    assert abs(res["charge_sharded"][-1] - res["charge_analysis"]) < 1e-6
    np.testing.assert_allclose(res["charge_sharded"], res["charge_flat"],
                               atol=1e-6)


def test_checkpoint_restart_bitwise_sharded(engine_result):
    res = engine_result["ckpt"]
    assert res == {"pos_bitwise": True, "spin_bitwise": True,
                   "vel_bitwise": True, "rebuilds_match": True}


def test_replica_axis_device_sharding(engine_result):
    """shard_replicas spreads the replica axis over devices: bitwise
    parity with the unsharded run, and restore re-places the carry
    sharded (then resumes bitwise)."""
    res = engine_result["replica_shard"]
    assert res == {"matches_unsharded": True, "restore_sharded": True,
                   "resume_bitwise": True}


def test_nep_kernel_rides_sharded_plan(engine_result):
    """use_kernel=True through the domain decomposition: energies/forces
    match the flat kernel path at f32 roundoff; adjoint accumulators move
    in one q_Fp halo per evaluation."""
    res = engine_result["kernel"]
    assert res["e0"] < 1e-6, res   # relative |dE|/|E|: a few f32 ulps
    assert res["f0"] < 1e-6, res
    assert res["h0"] < 1e-6, res
    assert res["qfp_exchanges"] >= 1, res
    assert res["pos"] < 1e-4, res
    assert res["spin"] < 1e-3, res


# ---------------------------------------------------------------- in-process

def _engine(plan=None, seed=3, obs=("energy", "kinetic", "magnetization",
                                    "charge", "pitch"), **kw):
    lat = simple_cubic()
    st = init_state(lat, (4, 4, 4), temperature=500.0, spin_init="helix_x",
                    key=jax.random.PRNGKey(seed))
    return st, Engine(potential=HeisenbergDMIModel(d0=0.008),
                      cfg=IntegratorConfig(dt=2e-3, spin_alpha=0.05,
                                           lattice_gamma=1.0),
                      state=st, masses=jnp.asarray(lat.masses),
                      magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0,
                      capacity=8, skin=0.2, plan=plan, observables=obs,
                      temperature=100.0, **kw)


def test_flat_observables_reproduce_analysis():
    _, eng = _engine()
    eng.run(30, jax.random.PRNGKey(0), chunk=10)
    st = eng.state
    mag = (jnp.asarray(simple_cubic().moments) > 0)[
        jnp.maximum(st.types, 0)]
    np.testing.assert_allclose(
        eng.trace.values["charge"][-1],
        np.asarray(topological_charge(st.pos, st.spin, st.box,
                                      grid=(32, 32))), atol=1e-6)
    np.testing.assert_allclose(
        eng.trace.values["magnetization"][-1],
        np.asarray(magnetization(st.spin, mask=mag)), atol=1e-6)
    np.testing.assert_allclose(
        eng.trace.values["pitch"][-1],
        np.asarray(helix_pitch(st.pos, st.spin, st.box, axis=0,
                               n_bins=64)), atol=1e-6)


def test_obs_every_streams_in_scan():
    _, eng = _engine(obs_every=5, obs=("energy", "magnetization"))
    eng.run(40, jax.random.PRNGKey(0), chunk=20)
    assert eng.trace.values["energy"].shape == (8,)
    assert eng.trace.values["magnetization"].shape == (8, 3)
    np.testing.assert_allclose(eng.trace.time,
                               2e-3 * np.arange(5, 45, 5), rtol=1e-6)
    assert eng._chunk_fn._cache_size() == 1
    with pytest.raises(ValueError, match="multiple"):
        eng.run(30, jax.random.PRNGKey(0), chunk=7)


def test_checkpoint_restart_bitwise_flat_and_replica():
    for plan in (None, Replicated(3)):
        _, a = _engine(plan=plan)
        a.run(60, jax.random.PRNGKey(5), chunk=20)
        with tempfile.TemporaryDirectory() as d:
            _, b = _engine(plan=plan)
            b.run(40, jax.random.PRNGKey(5), chunk=20, checkpoint_dir=d)
            _, c = _engine(plan=plan)
            key = c.restore(d)
            c.run(20, key, chunk=20)
        label = type(plan).__name__ if plan else "flat"
        assert bool(jnp.all(a.state.pos == c.state.pos)), label
        assert bool(jnp.all(a.state.spin == c.state.spin)), label
        assert bool(jnp.all(a.state.vel == c.state.vel)), label


def test_resume_flag_picks_up_newest_checkpoint():
    _, a = _engine()
    a.run(40, jax.random.PRNGKey(9), chunk=20)
    with tempfile.TemporaryDirectory() as d:
        _, b = _engine()
        b.run(20, jax.random.PRNGKey(9), chunk=20, checkpoint_dir=d)
        _, c = _engine()
        # the passed key is replaced by the checkpointed one on resume;
        # the remaining 20 steps land exactly on a's uninterrupted 40
        c.run(20, jax.random.PRNGKey(123), chunk=20, checkpoint_dir=d,
              resume=True)
    assert bool(jnp.all(a.state.pos == c.state.pos))
    assert bool(jnp.all(a.state.spin == c.state.spin))


def test_nep_spin_through_replica_plan():
    """NEP-SPIN (autodiff) drives the vmapped-replica plan under a
    field-cooling schedule - the evaluator and plan axes compose (closes
    the ROADMAP 'NEP through the ensemble' item as configuration)."""
    from repro.core.descriptor import NEPSpinSpec
    from repro.core.potential import NEPSpinPotential, init_params

    lat = simple_cubic()
    st = init_state(lat, (3, 3, 3), temperature=300.0, spin_init="helix_x",
                    key=jax.random.PRNGKey(1), dtype=jnp.float32)
    spec = NEPSpinSpec(l_max=2, n_ang=2, n_rad=4, n_spin=2, basis_size=6)
    params = init_params(spec, jax.random.PRNGKey(0), dtype=jnp.float32)
    temp, field = protocol.field_cooling(200.0, 20.0, 5.0, t_hold=0.004,
                                         t_ramp=0.02)
    eng = Engine(potential=NEPSpinPotential(spec, params),
                 cfg=IntegratorConfig(dt=2e-3, spin_alpha=0.05,
                                      lattice_gamma=1.0),
                 state=st, masses=jnp.asarray(lat.masses, jnp.float32),
                 magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0,
                 capacity=16, skin=0.3, plan=Replicated(2),
                 temperature=temp, field=field,
                 observables=("energy", "charge"))
    eng.run(20, jax.random.PRNGKey(3), chunk=10)
    assert eng.trace.values["energy"].shape == (2, 2)
    assert np.isfinite(eng.trace.values["energy"]).all()
    assert np.isfinite(np.asarray(eng.state.spin)).all()
    # thermostat streams differ per replica -> trajectories decorrelate
    assert float(jnp.abs(eng.state.spin[0] - eng.state.spin[1]).max()) > 0


def test_schedule_on_flat_plan_tracks_constant_segments():
    """A constant schedule and the same constant value produce identical
    trajectories (the schedule axis is orthogonal to the others)."""
    _, a = _engine()
    _, b = _engine()
    a.temperature = 100.0
    b.temperature = protocol.constant(100.0)
    a.run(30, jax.random.PRNGKey(2), chunk=10)
    b.run(30, jax.random.PRNGKey(2), chunk=10)
    np.testing.assert_array_equal(np.asarray(a.state.spin),
                                  np.asarray(b.state.spin))
    np.testing.assert_array_equal(np.asarray(a.state.pos),
                                  np.asarray(b.state.pos))
