"""Distributed (multi-device) correctness - run in a subprocess so the
forced 8-device CPU environment never leaks into the main test process."""
import json
import os
import subprocess
import sys

import jax
import pytest

# The distributed path drives the explicit-mesh APIs (jax.set_mesh,
# jax.sharding.AxisType, make_mesh(axis_types=...)).  On older jax (< 0.5)
# those don't exist and the subprocess would die in setup with an opaque
# AttributeError - skip the whole module cleanly instead.
pytestmark = pytest.mark.skipif(
    not (hasattr(jax, "set_mesh") and hasattr(jax.sharding, "AxisType")),
    reason="needs jax >= 0.5 explicit-mesh APIs (jax.set_mesh, "
           "jax.sharding.AxisType) for the multi-device domain path")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp, numpy as np
from repro.md.lattice import simple_cubic
from repro.md.state import init_state
from repro.md.neighbor import dense_neighbor_table
from repro.core.descriptor import NEPSpinSpec
from repro.core.potential import init_params, energy_forces_field
from repro.parallel.domain import (DomainSpec, pack_domain,
                                   distributed_energy_fn, unpack_domain)
from repro.utils.hlo import collective_bytes

out = {}
lat = simple_cubic()
st = init_state(lat, (5, 5, 5), temperature=300.0, spin_init="random",
                key=jax.random.PRNGKey(7))
spec = NEPSpinSpec(n_types=1, l_max=2, n_ang=2, n_rad=4, n_spin=2,
                   basis_size=6)
params = init_params(spec, jax.random.PRNGKey(0))
tab = dense_neighbor_table(st.pos, st.box, 5.0, 40)
e_ref, f_ref, h_ref = energy_forces_field(spec, params, st.pos, st.spin,
                                          st.types, tab, st.box)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
dspec = DomainSpec(cells=(4, 4, 4), capacity=8, cutoff=5.0,
                   box=tuple(np.asarray(st.box)),
                   axis_map=("pod", "data", "model"))
dspec.check()
dst = pack_domain(dspec, st.pos, st.vel, st.spin, st.types)
efn, effn = distributed_energy_fn(spec, dspec, mesh)
with jax.set_mesh(mesh):
    e_d = efn(params, dst)
    e2, f_d, h_d = effn(params, dst)
out["e_diff"] = float(abs(e_ref - e_d))
pos_u, f_u, h_u, _ = unpack_domain(dst._replace(vel=f_d, spin=h_d))
pos_o = np.asarray(st.pos)
idx = [int(np.argmin(np.sum((pos_o - p) ** 2, -1))) for p in pos_u]
out["f_err"] = float(np.abs(np.asarray(f_u) - np.asarray(f_ref)[idx]).max())
out["h_err"] = float(np.abs(np.asarray(h_u) - np.asarray(h_ref)[idx]).max())

# halo-exchange collectives must appear in the compiled module
with jax.set_mesh(mesh):
    hlo = jax.jit(lambda d: efn(params, d)).lower(dst).compile().as_text()
out["coll_bytes"] = collective_bytes(hlo)

# pruned (pre-staged) evaluation path must match the stencil path
from repro.parallel.domain import distributed_energy_fn_pruned
build, effn_p = distributed_energy_fn_pruned(spec, dspec, mesh, capacity=32)
with jax.set_mesh(mesh):
    idx, nmask = build(dst.pos, dst.types, dst.mask)
    e_p, f_p, h_p = effn_p(params, dst.pos, dst.spin, dst.types, dst.mask,
                           idx, nmask)
out["pruned_e_diff"] = float(abs(e_p - e_d))
out["pruned_f_diff"] = float(jnp.abs(f_p - f_d).max())

# expert-parallel MoE (shard_map + all_to_all) must match dense dispatch
from repro.models.config import ArchConfig, MoECfg
from repro.models.moe import apply_moe_dense, apply_moe_ep, init_moe
cfgm = ArchConfig(name="t", family="moe", n_layers=1, d_model=32, vocab=64,
                  act="swiglu", dtype="float32",
                  moe=MoECfg(n_experts=8, top_k=2, n_shared=1,
                             d_ff_expert=16, router="sigmoid",
                             capacity_factor=8.0))
mesh2 = jax.make_mesh((2, 4), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
pm = init_moe(cfgm, jax.random.PRNGKey(0))
xm = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
with jax.set_mesh(mesh2):
    y_ep, _ = jax.jit(lambda p, x: apply_moe_ep(cfgm, p, x, mesh2))(pm, xm)
    g = jax.grad(lambda p: jnp.sum(
        apply_moe_ep(cfgm, p, xm, mesh2)[0] ** 2))(pm)
y_dn, _ = apply_moe_dense(cfgm, pm, xm)
out["moe_ep_diff"] = float(jnp.abs(y_ep - y_dn).max())
out["moe_ep_grads_finite"] = bool(all(
    np.isfinite(np.asarray(v)).all()
    for v in jax.tree_util.tree_leaves(g)))

# production TPU composition: Pallas kernels over the pruned table with
# halo-exchanged adjoint accumulators (q_Fp exchange)
from repro.parallel.domain import distributed_kernel_force_fn
buildk, effn_k = distributed_kernel_force_fn(spec, dspec, mesh,
                                             capacity=32)
with jax.set_mesh(mesh):
    idxk, nmaskk = buildk(dst.pos, dst.types, dst.mask)
    e_k, f_k, h_k = effn_k(params, dst.pos, dst.spin, dst.types, dst.mask,
                           idxk, nmaskk)
out["kernel_e_diff"] = float(abs(e_k - e_d))
out["kernel_f_diff"] = float(jnp.abs(f_k - f_d).max())
out["kernel_h_diff"] = float(jnp.abs(h_k - h_d).max())

# checkpoint round-trip of the distributed state
from repro.ckpt.checkpoint import save_checkpoint, load_checkpoint
import tempfile
tmp = tempfile.mkdtemp()
save_checkpoint(tmp, 3, dst)
loaded, step = load_checkpoint(tmp, dst)
out["ckpt_ok"] = bool(step == 3 and all(
    np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(dst),
                    jax.tree_util.tree_leaves(loaded))))
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_distributed_energy_matches_reference(result):
    assert result["e_diff"] < 1e-10


def test_distributed_forces_and_fields_match(result):
    assert result["f_err"] < 1e-12
    assert result["h_err"] < 1e-12


def test_halo_exchange_produces_collectives(result):
    assert result["coll_bytes"] > 0


def test_distributed_state_checkpoint_roundtrip(result):
    assert result["ckpt_ok"]


def test_pruned_prestaged_path_matches_stencil(result):
    """The paper's Phase-A/B pre-staging (pruned top-M table) must be exact
    vs the 27-stencil streaming evaluation (EXPERIMENTS.md SPerf cell 3)."""
    assert result["pruned_e_diff"] < 1e-8
    assert result["pruned_f_diff"] < 1e-10


def test_expert_parallel_moe_matches_dense(result):
    """shard_map+all_to_all EP dispatch == dense one-hot dispatch
    (EXPERIMENTS.md SPerf cell 1), with finite gradients."""
    assert result["moe_ep_diff"] < 1e-4
    assert result["moe_ep_grads_finite"]


def test_pallas_kernels_over_domain_match_autodiff(result):
    """The full production path (fused Pallas kernels + pruned table +
    halo-exchanged adjoints) must match the autodiff stencil evaluation."""
    assert result["kernel_e_diff"] < 1e-8
    assert result["kernel_f_diff"] < 1e-10
    assert result["kernel_h_diff"] < 1e-10
