"""FP64 validation in a subprocess (x64 must not leak into other tests).

Quantifies the paper's full-FP64 claim: exact |S| conservation, clean
O(dt^2) energy scaling, and the f32-vs-f64 drift gap recorded in
EXPERIMENTS.md §Precision.

Uses the paper's self-consistent midpoint spin update (Sec. 5-A3): the
explicit one-shot rotation carries a secular energy drift linear in dt at
fixed total time, which buries the dt^2 shadow term (measured endpoint
ratios ~2.7/1.9/2.0 across successive dt halvings); the converged midpoint
scheme restores a clean ~4.35 ratio and a ~70x smaller absolute drift.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import jax
jax.config.update("jax_enable_x64", True)
import json
import jax.numpy as jnp, numpy as np
from repro.core.hamiltonian import HeisenbergDMIModel
from repro.md.integrator import IntegratorConfig
from repro.md.lattice import simple_cubic
from repro.md.simulate import Simulation
from repro.md.state import init_state, kinetic_energy

def total_e(lat, sim):
    return sim.energy + float(kinetic_energy(sim.state,
                                             jnp.asarray(lat.masses)))

def run(dt, steps, key=5):
    lat = simple_cubic()
    st = init_state(lat, (4, 4, 4), temperature=150.0, spin_init="random",
                    key=jax.random.PRNGKey(key))
    assert st.pos.dtype == jnp.float64
    ham = HeisenbergDMIModel(d0=0.008, ka=0.001)
    cfg = IntegratorConfig(dt=dt, midpoint=True, midpoint_iters=3)
    sim = Simulation(potential=ham, cfg=cfg, state=st,
                     masses=jnp.asarray(lat.masses),
                     magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0,
                     capacity=8)
    e0 = total_e(lat, sim)
    sim.run(steps, jax.random.PRNGKey(1), chunk=50)
    dev = float(jnp.abs(jnp.linalg.norm(sim.state.spin, axis=-1) - 1).max())
    return abs(total_e(lat, sim) - e0), dev

out = {}
d1, s1 = run(4e-3, 200)
d2, s2 = run(2e-3, 400)
out["drift_dt_large"] = d1
out["drift_dt_half"] = d2
out["ratio"] = d1 / max(d2, 1e-300)
out["spin_norm_dev"] = max(s1, s2)
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_f64_spin_norm_machine_precision(result):
    assert result["spin_norm_dev"] < 1e-12


def test_f64_energy_scaling_second_order(result):
    # symplectic shadow-energy error is O(dt^2) but endpoint drift is
    # noisy; require at least quadratic improvement
    assert 2.5 < result["ratio"] < 60.0, result


def test_f64_drift_small(result):
    # calibrated: ~3.3e-7 eV/atom over 400 midpoint steps at dt=2e-3
    assert result["drift_dt_half"] / 64 < 2e-6  # eV/atom
