"""Neighbor-table construction correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.md.lattice import b20_fege, simple_cubic
from repro.md.neighbor import (cell_neighbor_table, dense_neighbor_table,
                               needs_rebuild, bin_atoms)
from repro.md.state import init_state


def _pairs(table, n):
    s = set()
    idx = np.asarray(table.idx)
    mask = np.asarray(table.mask)
    for i in range(n):
        for m in range(idx.shape[1]):
            if mask[i, m]:
                s.add((i, int(idx[i, m])))
    return s


def test_dense_vs_cell_equivalence():
    lat = b20_fege()
    st = init_state(lat, (4, 4, 4), temperature=300.0,
                    key=jax.random.PRNGKey(0))
    dense = dense_neighbor_table(st.pos, st.box, 4.0, 96, skin=0.3)
    cell = cell_neighbor_table(st.pos, st.box, 4.0, 96, cell_capacity=24,
                               skin=0.3)
    assert _pairs(dense, st.n_atoms) == _pairs(cell, st.n_atoms)


def test_table_symmetric():
    """j in nbr(i) <=> i in nbr(j) (required by the pair-symmetric force
    kernel)."""
    lat = simple_cubic()
    st = init_state(lat, (4, 4, 4), key=jax.random.PRNGKey(1))
    tab = dense_neighbor_table(st.pos, st.box, 5.0, 12)
    pairs = _pairs(tab, st.n_atoms)
    assert all((j, i) in pairs for (i, j) in pairs)


def test_needs_rebuild_half_skin():
    lat = simple_cubic()
    st = init_state(lat, (3, 3, 3), key=jax.random.PRNGKey(2))
    tab = dense_neighbor_table(st.pos, st.box, 5.0, 12, skin=0.5)
    assert not bool(needs_rebuild(tab, st.pos, st.box, 0.5))
    moved = st.pos.at[0, 0].add(0.3)
    assert bool(needs_rebuild(tab, moved, st.box, 0.5))


def test_dense_capacity_exceeds_n():
    """capacity > n: the padded columns must be masked out and self-padded
    (regression for the old conditional re-pad of ``mask``, which rebuilt
    ``idx`` from a stale pre-pad mask)."""
    lat = simple_cubic()
    st = init_state(lat, (2, 2, 2), key=jax.random.PRNGKey(4))
    n = st.n_atoms
    ref = dense_neighbor_table(st.pos, st.box, 5.0, n - 1)
    big = dense_neighbor_table(st.pos, st.box, 5.0, n + 5)
    assert big.idx.shape == (n, n + 5) and big.mask.shape == (n, n + 5)
    # same neighbor set; the extra columns are all invalid
    assert _pairs(big, n) == _pairs(ref, n)
    idx, mask = np.asarray(big.idx), np.asarray(big.mask)
    assert not mask[:, n:].any()
    rows = np.broadcast_to(np.arange(n)[:, None], idx.shape)
    np.testing.assert_array_equal(idx[~mask], rows[~mask])  # self-padded


def test_bin_atoms_no_overflow_and_complete():
    lat = b20_fege()
    st = init_state(lat, (3, 3, 3), key=jax.random.PRNGKey(3))
    grid, mask, overflow = bin_atoms(st.pos, st.box, (3, 3, 3), 12)
    assert not bool(overflow)
    ids = np.asarray(grid)[np.asarray(mask)]
    assert sorted(ids.tolist()) == list(range(st.n_atoms))
