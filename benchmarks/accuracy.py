"""Paper Table IV analogue: accuracy of NEP-SPIN vs baselines on a
held-out FeGe spin-lattice validation set (labels from the synthetic
constrained-DFT oracle).

Models compared:
  nepspin        full spin-aware NEP (the paper's model)
  nep-nospin     structural NEP without magnetic channels - shows why the
                 spin extension is required (torque RMSE = label scale)
  classical-fit  fixed-coupling spin Hamiltonian with least-squares-fitted
                 (J0, D0) - the 'DFT-parameterized spin Hamiltonian'
                 baseline class (refs [14], [24]); transferability-limited

CSV: name, us_per_call(=fit seconds*1e6), derived=E/F/H RMSEs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core.descriptor import NEPSpinSpec
from repro.core.hamiltonian import HeisenbergDMIModel
from repro.core.training import fit_adam, generate_dataset, rmse_metrics


def main() -> list[str]:
    key = jax.random.PRNGKey(0)
    from repro.md.lattice import b20_fege
    lat = b20_fege()
    oracle = HeisenbergDMIModel(r0=2.45, morse_de=0.4, morse_alpha=1.6,
                                d0=0.005, kpd=0.001)
    train = generate_dataset(oracle, lat, (2, 2, 2), 24, key)
    val = generate_dataset(oracle, lat, (2, 2, 2), 8,
                           jax.random.PRNGKey(99))
    rows = []

    for name, spec_kw in (("nepspin", dict()),
                          ("nep-nospin", dict(spin=False))):
        spec = NEPSpinSpec(l_max=2, n_ang=2, n_rad=4, n_spin=3,
                           basis_size=6, **spec_kw)
        t0 = time.time()
        params, _ = fit_adam(spec, train, key, steps=150)
        dt = time.time() - t0
        m = rmse_metrics(spec, params, val)
        rows.append(row(
            f"accuracy/{name}", dt * 1e6,
            f"E={float(m['e_rmse_per_atom'])*1e3:.3f}meV/atom|"
            f"F={float(m['f_rmse'])*1e3:.2f}meV/A|"
            f"H={float(m['h_rmse'])*1e3:.2f}meV/muB"))

    # classical fixed-coupling baseline: least-squares (J0, D0) via scan
    t0 = time.time()
    best, best_rmse = None, np.inf
    for j0 in np.linspace(0.008, 0.03, 6):
        for d0 in np.linspace(0.0, 0.01, 6):
            cand = HeisenbergDMIModel(r0=2.45, morse_de=0.4,
                                      morse_alpha=1.6, j0=j0, d0=d0)
            from repro.md.neighbor import dense_neighbor_table
            e, f, h = jax.lax.map(
                lambda xs: cand.energy_forces_field(
                    xs[0], xs[1], val.types,
                    dense_neighbor_table(xs[0], val.box, cand.cutoff, 64),
                    val.box), (val.pos, val.spin))
            r = float(jnp.sqrt(jnp.mean((h - val.h_ref) ** 2)))
            if r < best_rmse:
                best_rmse, best = r, (j0, d0, e, f, h)
    dt = time.time() - t0
    j0, d0, e, f, h = best
    n = val.pos.shape[1]
    rows.append(row(
        "accuracy/classical-fit", dt * 1e6,
        f"E={float(jnp.sqrt(jnp.mean((e-val.e_ref)**2)))/n*1e3:.3f}meV/atom|"
        f"F={float(jnp.sqrt(jnp.mean((f-val.f_ref)**2)))*1e3:.2f}meV/A|"
        f"H={best_rmse*1e3:.2f}meV/muB|J0={j0:.4f}|D0={d0:.4f}"))
    return rows


if __name__ == "__main__":
    main()
