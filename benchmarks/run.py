"""Benchmark harness: one module per paper table/figure.

  ablation.py    - Fig. 5  single-node optimization ablation
  throughput.py  - Fig. 6 / Table I  atom-step/s vs system size, TtS
  scaling.py     - Fig. 7/8 / Table V  weak & strong scaling projections
  accuracy.py    - Table IV  NEP-SPIN vs baseline accuracy
  kernels.py     - kernel-level microbenchmarks (fused vs reference)
  ensemble.py    - Fig. 9 scenario engine: vmapped replicas vs sequential
  md_loop.py     - fused in-scan hot loop vs pre-fusion driver (PR 2)

Prints ``name,us_per_call,derived`` CSV rows.  ``--smoke`` (or
BENCH_SMOKE=1) runs every benchmark for 1 iteration on downscaled problems
so perf code can't silently rot (wired into scripts/ci.sh --smoke).
"""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    if "--smoke" in sys.argv[1:]:
        os.environ["BENCH_SMOKE"] = "1"
    from benchmarks import (ablation, accuracy, ensemble, kernels, md_loop,
                            scaling, throughput)
    print("name,us_per_call,derived")
    failures = []
    for mod in (kernels, ablation, throughput, scaling, accuracy, ensemble,
                md_loop):
        try:
            mod.main()
        except Exception as e:
            failures.append((mod.__name__, e))
            traceback.print_exc()
    if failures:
        print(f"FAILED: {[f[0] for f in failures]}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
