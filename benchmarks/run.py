"""Benchmark harness: one registered module per paper table/figure.

  ablation    - Fig. 5  single-node optimization ablation
  throughput  - Fig. 6 / Table I  atom-step/s vs system size, TtS
  scaling     - Fig. 7/8 / Table V  weak scaling of the SHARDED fused loop
                (writes BENCH_scaling.json, incl. the nep_kernel entry)
  accuracy    - Table IV  NEP-SPIN vs baseline accuracy
  kernels     - kernel-level microbenchmarks (fused vs reference)
  ensemble    - Fig. 9 scenario engine: vmapped replicas vs sequential
  serve       - serving tier: packed drain jobs/s, WAL journal overhead,
                recovery-replay latency (writes BENCH_serve.json)
  md_loop     - fused in-scan hot loop vs pre-fusion driver
                (writes BENCH_md_loop.json)

One command refreshes every emitted ``BENCH_*.json`` (each stamped with
jax-version/backend/device-count provenance via ``benchmarks.common``):

  PYTHONPATH=src python -m benchmarks.run                 # all modules
  PYTHONPATH=src python -m benchmarks.run --only md_loop,scaling

Prints ``name,us_per_call,derived`` CSV rows.  ``--smoke`` (or
BENCH_SMOKE=1) runs every benchmark for 1 iteration on downscaled problems
so perf code can't silently rot (wired into scripts/ci.sh --smoke).
``--strict`` (or BENCH_STRICT=1) promotes perf-regression warnings to hard
failures - currently the md_loop kernel gates: dispatch must resolve to a
compiled executor, and on full runs ``nep_kernel.vs_autodiff >= 1.0``.
"""
from __future__ import annotations

import os
import sys
import traceback

# registration order = execution order (cheap first)
REGISTRY = ("kernels", "ablation", "throughput", "scaling", "accuracy",
            "ensemble", "serve", "md_loop")


def main() -> None:
    argv = sys.argv[1:]
    if "--smoke" in argv:
        os.environ["BENCH_SMOKE"] = "1"
    if "--strict" in argv:
        os.environ["BENCH_STRICT"] = "1"
    selected = list(REGISTRY)
    if "--only" in argv:
        if argv.index("--only") + 1 >= len(argv):
            sys.exit(f"--only needs a comma-separated subset of: "
                     f"{', '.join(REGISTRY)}")
        names = argv[argv.index("--only") + 1].split(",")
        unknown = [n for n in names if n not in REGISTRY]
        if unknown:
            sys.exit(f"unknown benchmark(s) {unknown}; registry: "
                     f"{', '.join(REGISTRY)}")
        selected = names
    import importlib
    modules = [importlib.import_module(f"benchmarks.{n}") for n in selected]
    print("name,us_per_call,derived")
    failures = []
    for mod in modules:
        try:
            mod.main()
        except Exception as e:
            failures.append((mod.__name__, e))
            traceback.print_exc()
    if failures:
        print(f"FAILED: {[f[0] for f in failures]}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
