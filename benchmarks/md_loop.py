"""Fused MD hot loop vs the pre-fusion driver: steps/s + recompile count.

The PR-2 acceptance benchmark: chunked stepping at N~4k atoms through

* the FUSED driver - whole chunk (half-skin test, ``lax.cond`` table
  rebuild, gather-once force evaluation) inside one compiled ``lax.scan``,
  compiled exactly once per geometry; and
* the LEGACY driver (``fused=False``) - host-side skin test between chunks
  and a fresh jit of the step closure on every rebuild, i.e. the pre-PR
  orchestration cost this PR removes.

Both paths are warmed up (initial compile excluded), then timed over a run
whose thermal motion trips >=3 neighbor rebuilds - so the legacy number
pays its recompiles and per-chunk host syncs, exactly as it did in
production.  Compilations are counted two ways: ``jax.monitoring``
backend-compile events observed during the timed run, and the jit cache
size of the fused chunk (must be exactly 1).

Emits machine-readable ``BENCH_md_loop.json`` (repo root) so the perf
trajectory is tracked from this PR onward, plus a telemetry-instrumented
fused run whose overhead vs the bare fused path is measured (must stay
<5%, with zero recompiles - telemetry never retraces the chunk).  The
instrumented run's runlog (``RUNLOG_md_loop.jsonl`` at the repo root on
full runs, a tempfile in smoke) is stamped with a ``benchmark`` record
carrying per-path steps/s and ``nep_kernel.vs_autodiff``; when the kernel
path regresses below the previously recorded ``BENCH_md_loop.json``
value, a loud log-only warning is printed (the perf trajectory file is
still overwritten).  Under ``--strict`` / BENCH_STRICT=1 the kernel path
is HARD-gated instead: dispatch must resolve to a compiled executor (not
interpret), and on full runs ``nep_kernel.vs_autodiff >= 1.0`` - the
kernel must beat the autodiff fused loop, not just exist.  Full runs also
stamp a ``roofline`` record (repro.launch.roofline.nep_report): analytic
per-atom descriptor FLOPs/bytes vs jaxpr-measured K1/gather/K2 costs and
the abar_j gather bytes (the dominant HBM term).  CSV rows:
name, us_per_call (=us/step), derived=steps/s|speedup|rebuilds|compiles.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.common import SMOKE, row
from repro.core.descriptor import NEPSpinSpec
from repro.core.hamiltonian import HeisenbergDMIModel
from repro.core.potential import NEPSpinPotential, init_params
from repro.md.integrator import IntegratorConfig
from repro.md.lattice import simple_cubic
from repro.md.simulate import Simulation
from repro.md.state import init_state

STRICT = bool(os.environ.get("BENCH_STRICT"))

CELLS = (4, 4, 4) if SMOKE else (16, 16, 16)       # 64 / 4096 atoms
STEPS = {"heisenberg": 40 if SMOKE else 400, "nep": 20 if SMOKE else 60,
         "nep_kernel": 4 if SMOKE else 20}
CHUNK = 20
SKIN = 0.2   # half-skin 0.1 A: 500 K thermal motion trips rebuilds fast


class _CompileCounter:
    """Counts XLA backend compiles via jax.monitoring duration events."""

    def __init__(self):
        self.count = 0
        jax.monitoring.register_event_duration_secs_listener(self._on_event)

    def _on_event(self, name, _dur, **kw):
        if name == "/jax/core/compile/backend_compile_duration":
            self.count += 1


_COMPILES = _CompileCounter()


def _sim(potential, fused: bool) -> Simulation:
    lat = simple_cubic()
    st = init_state(lat, CELLS, temperature=500.0, spin_init="helix_x",
                    key=jax.random.PRNGKey(0), dtype=jnp.float32)
    return Simulation(
        potential=potential, cfg=IntegratorConfig(dt=2e-3), state=st,
        masses=jnp.asarray(lat.masses, jnp.float32),
        magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0, capacity=8,
        skin=SKIN, use_cell_list=not SMOKE, fused=fused)


def _time_run(sim: Simulation, n_steps: int,
              telemetry=None) -> tuple[float, int, int]:
    """(wall s, compiles, rebuilds) observed during a warmed-up run."""
    sim.run(CHUNK, jax.random.PRNGKey(1), chunk=CHUNK)  # warmup compile
    jax.block_until_ready(sim.state.pos)
    c0, r0 = _COMPILES.count, sim.n_rebuilds
    t0 = time.perf_counter()
    sim.run(n_steps, jax.random.PRNGKey(2), chunk=CHUNK, telemetry=telemetry)
    jax.block_until_ready(sim.state.pos)
    return (time.perf_counter() - t0, _COMPILES.count - c0,
            sim.n_rebuilds - r0)


def bench_potential(name: str, make_potential,
                    paths=(("fused", True), ("legacy", False))) -> dict:
    n_steps = STEPS[name]
    res = {"n_steps": n_steps}
    for label, fused in paths:
        sim = _sim(make_potential(), fused)
        dt, compiles, rebuilds = _time_run(sim, n_steps)
        res[label] = {
            "steps_per_s": n_steps / dt,
            "wall_s": dt,
            "rebuilds": rebuilds,
            "compiles_during_run": compiles,
        }
        res["n_atoms"] = sim.state.n_atoms
        if fused:
            res[label]["chunk_cache_size"] = sim._chunk_fn._cache_size()
    if "legacy" in res:
        res["speedup"] = (res["fused"]["steps_per_s"]
                          / res["legacy"]["steps_per_s"])
    return res


def bench_telemetry(base: dict, runlog_path: str) -> dict:
    """Fused heisenberg run with full telemetry (runlog + health checks):
    the instrumentation overhead vs the bare fused path, which must not
    retrace the chunk (health signals live inside the always-compiled
    body; only the host-side bookkeeping is new)."""
    from repro.telemetry import Telemetry

    n_steps = STEPS["heisenberg"]
    sim = _sim(HeisenbergDMIModel(d0=0.01), True)
    dt, compiles, _ = _time_run(
        sim, n_steps, telemetry=Telemetry(runlog=runlog_path))
    rate = n_steps / dt
    bare = base["fused"]["steps_per_s"]
    overhead = 1.0 - rate / bare
    # the 5% budget applies at full size; at smoke scale (64 atoms,
    # ~0.3 ms/step) the fixed per-chunk host bookkeeping dominates and a
    # warning would fire on every CI run
    if overhead > 0.05 and not SMOKE:
        print(f"WARNING: telemetry overhead {overhead:.1%} exceeds the "
              f"5% budget ({rate:.1f} vs bare {bare:.1f} steps/s)",
              file=sys.stderr)
    return {"steps_per_s": rate, "compiles_during_run": compiles,
            "overhead_vs_fused": overhead, "runlog": runlog_path}


def main() -> list[str]:
    out = {"n_atoms": None, "chunk": CHUNK, "skin": SKIN, "smoke": SMOKE,
           "potentials": {}}
    rows = []
    cases = [("heisenberg", lambda: HeisenbergDMIModel(d0=0.01), None)]
    spec = NEPSpinSpec(l_max=2, n_ang=2, n_rad=4, n_spin=2, basis_size=6)
    params = init_params(spec, jax.random.PRNGKey(0), dtype=jnp.float32)
    cases.append(("nep", lambda: NEPSpinPotential(spec, params), None))
    # fused NEP kernel path through the SAME fused loop (mode "auto":
    # compiled lax.map tiling on CPU; the identical kernel bodies compile
    # to MXU Pallas kernels on TPU).  Tracked fused-only: its reference
    # point is the autodiff fused path, so kernel-path regressions show up
    # as a vs_autodiff drift (gated >= 1.0 under --strict).
    cases.append(("nep_kernel", lambda: NEPSpinPotential(
        spec, params, use_kernel=True), (("fused", True),)))
    for name, make, paths in cases:
        res = (bench_potential(name, make) if paths is None
               else bench_potential(name, make, paths))
        out["n_atoms"] = res["n_atoms"]
        out["potentials"][name] = res
        for label in ("fused", "legacy"):
            if label not in res:
                continue
            r = res[label]
            ratio = (f"{res['speedup']:.2f}x|" if "speedup" in res else "")
            rows.append(row(
                f"md_loop/{name}/{label}/N={res['n_atoms']}",
                1e6 / r["steps_per_s"],
                f"{r['steps_per_s']:.1f} steps/s|"
                f"{ratio}"
                f"{r['rebuilds']} rebuilds|"
                f"{r['compiles_during_run']} compiles"))
        fused = res["fused"]
        if not SMOKE:
            # acceptance: one compiled chunk across an in-scan-rebuild run
            # (the short kernel-path run sees fewer trips than the 400-step
            # autodiff runs)
            assert fused["rebuilds"] >= (1 if name == "nep_kernel" else 3), \
                fused
            assert fused["chunk_cache_size"] == 1, fused
            assert fused["compiles_during_run"] == 0, fused
    out["potentials"]["nep_kernel"]["vs_autodiff"] = (
        out["potentials"]["nep_kernel"]["fused"]["steps_per_s"]
        / out["potentials"]["nep"]["fused"]["steps_per_s"])
    from repro.kernels.nep import resolve_mode
    mode = resolve_mode("auto")
    out["potentials"]["nep_kernel"]["mode"] = mode
    if STRICT:
        # a regression to interpret-mode dispatch is a correctness artifact
        # masquerading as the fast path - fail fast, even at smoke scale
        assert mode != "interpret", mode

    if not SMOKE:
        # roofline: analytic descriptor model vs jaxpr-measured pipeline
        # cost at the bench geometry (same spec/capacity as the timed runs)
        from repro.launch.roofline import nep_report
        from repro.md.neighbor import dense_neighbor_table, gather_blocks
        lat = simple_cubic()
        st = init_state(lat, CELLS, temperature=500.0, spin_init="helix_x",
                        key=jax.random.PRNGKey(0), dtype=jnp.float32)
        tab = dense_neighbor_table(st.pos, st.box, 5.0, 8)
        nbh = gather_blocks(st.pos, st.types, tab, st.box)
        out["roofline"] = nep_report(spec, params, nbh, st.spin, st.types,
                                     mode=mode)

    # telemetry-instrumented fused run: overhead budget + no retrace
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    runlog_path = (os.path.join(root, "RUNLOG_md_loop.jsonl") if not SMOKE
                   else os.path.join(tempfile.mkdtemp(prefix="md_loop_"),
                                     "md_loop.jsonl"))
    tel = bench_telemetry(out["potentials"]["heisenberg"], runlog_path)
    out["telemetry"] = tel
    rows.append(row(
        f"md_loop/heisenberg/fused+telemetry/N={out['n_atoms']}",
        1e6 / tel["steps_per_s"],
        f"{tel['steps_per_s']:.1f} steps/s|"
        f"overhead={tel['overhead_vs_fused'] * 100:.1f}%|"
        f"{tel['compiles_during_run']} compiles"))
    if not SMOKE:
        assert tel["compiles_during_run"] == 0, tel
        # hard gate only at gross regression; the 5% budget warns above
        assert tel["overhead_vs_fused"] < 0.25, tel

    # stamp the benchmark verdicts into the runlog so the report / planner
    # layers see per-path perf next to the run records
    stamp = {
        "event": "benchmark", "t_wall": time.time(),
        "steps_per_s": {
            name: {lbl: p[lbl]["steps_per_s"]
                   for lbl in ("fused", "legacy") if lbl in p}
            for name, p in out["potentials"].items()},
        "nep_kernel": {
            "vs_autodiff": out["potentials"]["nep_kernel"]["vs_autodiff"]},
        "telemetry_overhead": tel["overhead_vs_fused"],
    }
    with open(runlog_path, "a") as fh:
        fh.write(json.dumps(stamp) + "\n")

    if not SMOKE:  # the tracked perf trajectory holds full-size runs only
        # loud log-only kernel-path regression check against the value
        # recorded by the previous full run (read before overwriting)
        bench_path = os.path.join(root, "BENCH_md_loop.json")
        prev = None
        if os.path.exists(bench_path):
            try:
                with open(bench_path) as fh:
                    prev = json.load(fh)["potentials"]["nep_kernel"][
                        "vs_autodiff"]
            except (KeyError, ValueError):
                prev = None
        new = out["potentials"]["nep_kernel"]["vs_autodiff"]
        if prev is not None and new < prev:
            print("=" * 72, file=sys.stderr)
            print(f"WARNING: nep_kernel path regressed: vs_autodiff "
                  f"{new:.3f} < recorded {prev:.3f} (BENCH_md_loop.json)",
                  file=sys.stderr)
            print("=" * 72, file=sys.stderr)
        # --strict: the kernel must BEAT the autodiff fused loop (the
        # PR-10 acceptance bar), not merely track its own history
        assert not STRICT or new >= 1.0, (
            f"nep_kernel.vs_autodiff {new:.3f} < 1.0 under --strict")
        from benchmarks.common import write_json
        write_json(bench_path, out)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
