"""Fused MD hot loop vs the pre-fusion driver: steps/s + recompile count.

The PR-2 acceptance benchmark: chunked stepping at N~4k atoms through

* the FUSED driver - whole chunk (half-skin test, ``lax.cond`` table
  rebuild, gather-once force evaluation) inside one compiled ``lax.scan``,
  compiled exactly once per geometry; and
* the LEGACY driver (``fused=False``) - host-side skin test between chunks
  and a fresh jit of the step closure on every rebuild, i.e. the pre-PR
  orchestration cost this PR removes.

Both paths are warmed up (initial compile excluded), then timed over a run
whose thermal motion trips >=3 neighbor rebuilds - so the legacy number
pays its recompiles and per-chunk host syncs, exactly as it did in
production.  Compilations are counted two ways: ``jax.monitoring``
backend-compile events observed during the timed run, and the jit cache
size of the fused chunk (must be exactly 1).

Emits machine-readable ``BENCH_md_loop.json`` (repo root) so the perf
trajectory is tracked from this PR onward.  CSV rows: name, us_per_call
(=us/step), derived=steps/s|speedup|rebuilds|compiles.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import SMOKE, row
from repro.core.descriptor import NEPSpinSpec
from repro.core.hamiltonian import HeisenbergDMIModel
from repro.core.potential import NEPSpinPotential, init_params
from repro.md.integrator import IntegratorConfig
from repro.md.lattice import simple_cubic
from repro.md.simulate import Simulation
from repro.md.state import init_state

CELLS = (4, 4, 4) if SMOKE else (16, 16, 16)       # 64 / 4096 atoms
STEPS = {"heisenberg": 40 if SMOKE else 400, "nep": 20 if SMOKE else 60,
         "nep_kernel": 4 if SMOKE else 20}
CHUNK = 20
SKIN = 0.2   # half-skin 0.1 A: 500 K thermal motion trips rebuilds fast


class _CompileCounter:
    """Counts XLA backend compiles via jax.monitoring duration events."""

    def __init__(self):
        self.count = 0
        jax.monitoring.register_event_duration_secs_listener(self._on_event)

    def _on_event(self, name, _dur, **kw):
        if name == "/jax/core/compile/backend_compile_duration":
            self.count += 1


_COMPILES = _CompileCounter()


def _sim(potential, fused: bool) -> Simulation:
    lat = simple_cubic()
    st = init_state(lat, CELLS, temperature=500.0, spin_init="helix_x",
                    key=jax.random.PRNGKey(0), dtype=jnp.float32)
    return Simulation(
        potential=potential, cfg=IntegratorConfig(dt=2e-3), state=st,
        masses=jnp.asarray(lat.masses, jnp.float32),
        magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0, capacity=8,
        skin=SKIN, use_cell_list=not SMOKE, fused=fused)


def _time_run(sim: Simulation, n_steps: int) -> tuple[float, int, int]:
    """(wall s, compiles, rebuilds) observed during a warmed-up run."""
    sim.run(CHUNK, jax.random.PRNGKey(1), chunk=CHUNK)  # warmup compile
    jax.block_until_ready(sim.state.pos)
    c0, r0 = _COMPILES.count, sim.n_rebuilds
    t0 = time.perf_counter()
    sim.run(n_steps, jax.random.PRNGKey(2), chunk=CHUNK)
    jax.block_until_ready(sim.state.pos)
    return (time.perf_counter() - t0, _COMPILES.count - c0,
            sim.n_rebuilds - r0)


def bench_potential(name: str, make_potential,
                    paths=(("fused", True), ("legacy", False))) -> dict:
    n_steps = STEPS[name]
    res = {"n_steps": n_steps}
    for label, fused in paths:
        sim = _sim(make_potential(), fused)
        dt, compiles, rebuilds = _time_run(sim, n_steps)
        res[label] = {
            "steps_per_s": n_steps / dt,
            "wall_s": dt,
            "rebuilds": rebuilds,
            "compiles_during_run": compiles,
        }
        res["n_atoms"] = sim.state.n_atoms
        if fused:
            res[label]["chunk_cache_size"] = sim._chunk_fn._cache_size()
    if "legacy" in res:
        res["speedup"] = (res["fused"]["steps_per_s"]
                          / res["legacy"]["steps_per_s"])
    return res


def main() -> list[str]:
    out = {"n_atoms": None, "chunk": CHUNK, "skin": SKIN, "smoke": SMOKE,
           "potentials": {}}
    rows = []
    cases = [("heisenberg", lambda: HeisenbergDMIModel(d0=0.01), None)]
    spec = NEPSpinSpec(l_max=2, n_ang=2, n_rad=4, n_spin=2, basis_size=6)
    params = init_params(spec, jax.random.PRNGKey(0), dtype=jnp.float32)
    cases.append(("nep", lambda: NEPSpinPotential(spec, params), None))
    # Pallas NEP kernel path through the SAME fused loop (interpret mode on
    # CPU; on TPU the identical pallas_call compiles to MXU kernels).
    # Tracked fused-only: its reference point is the autodiff fused path,
    # so kernel-path regressions show up as a vs_autodiff drift.
    cases.append(("nep_kernel", lambda: NEPSpinPotential(
        spec, params, use_kernel=True, interpret=True),
        (("fused", True),)))
    for name, make, paths in cases:
        res = (bench_potential(name, make) if paths is None
               else bench_potential(name, make, paths))
        out["n_atoms"] = res["n_atoms"]
        out["potentials"][name] = res
        for label in ("fused", "legacy"):
            if label not in res:
                continue
            r = res[label]
            ratio = (f"{res['speedup']:.2f}x|" if "speedup" in res else "")
            rows.append(row(
                f"md_loop/{name}/{label}/N={res['n_atoms']}",
                1e6 / r["steps_per_s"],
                f"{r['steps_per_s']:.1f} steps/s|"
                f"{ratio}"
                f"{r['rebuilds']} rebuilds|"
                f"{r['compiles_during_run']} compiles"))
        fused = res["fused"]
        if not SMOKE:
            # acceptance: one compiled chunk across an in-scan-rebuild run
            # (the short kernel-path run sees fewer trips than the 400-step
            # autodiff runs)
            assert fused["rebuilds"] >= (1 if name == "nep_kernel" else 3), \
                fused
            assert fused["chunk_cache_size"] == 1, fused
            assert fused["compiles_during_run"] == 0, fused
    out["potentials"]["nep_kernel"]["vs_autodiff"] = (
        out["potentials"]["nep_kernel"]["fused"]["steps_per_s"]
        / out["potentials"]["nep"]["fused"]["steps_per_s"])
    if not SMOKE:  # the tracked perf trajectory holds full-size runs only
        from benchmarks.common import write_json
        write_json(os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_md_loop.json"), out)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
