"""Kernel microbenchmarks: fused vs reference implementations.

Wall-clock here is CPU (Pallas interpret mode is a correctness harness, not
a perf path), so the *jnp* algorithmic variants are timed; Pallas-kernel
TPU performance is assessed structurally via the dry-run roofline.

CSV: name, us_per_call, derived.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit


def bench_attention() -> list[str]:
    from repro.models.attention import chunked_attention
    from repro.kernels.attention.ref import attention_ref
    rows = []
    b, s, h, hkv, d = 1, 2048, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))

    naive = jax.jit(lambda q, k, v: attention_ref(
        q.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d),
        v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)))
    flash = jax.jit(lambda q, k, v: chunked_attention(q, k, v, pos, pos,
                                                      kv_chunk=512))
    t0 = timeit(naive, q, k, v)
    t1 = timeit(flash, q, k, v)
    flops = 4 * b * h * s * s * d
    rows.append(row("kernels/attention-naive", t0 * 1e6,
                    f"{flops/t0/1e9:.1f}GFLOP/s"))
    rows.append(row("kernels/attention-flash-chunked", t1 * 1e6,
                    f"{flops/t1/1e9:.1f}GFLOP/s|{t0/t1:.2f}x"))
    return rows


def bench_ssd() -> list[str]:
    from repro.models.ssm import ssd_chunked, ssd_reference
    rows = []
    bs, s, h, p, g, n, chunk = 1, 2048, 8, 32, 1, 32, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (bs, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b = jax.random.normal(ks[3], (bs, s, g, n)) * 0.3
    c = jax.random.normal(ks[4], (bs, s, g, n)) * 0.3
    dsk = jnp.ones((h,))
    rec = jax.jit(lambda *a_: ssd_reference(*a_))
    chu = jax.jit(lambda *a_: ssd_chunked(*a_, chunk))
    t0 = timeit(rec, x, dt, a, b, c, dsk)
    t1 = timeit(chu, x, dt, a, b, c, dsk)
    rows.append(row("kernels/ssd-recurrence", t0 * 1e6, "1.00x"))
    rows.append(row("kernels/ssd-chunked", t1 * 1e6, f"{t0/t1:.2f}x"))
    return rows


def bench_nep() -> list[str]:
    """Fused NEP force evaluation throughput (the paper's hot kernel)."""
    from repro.core.descriptor import NEPSpinSpec
    from repro.core.potential import energy_forces_field, init_params
    from repro.md.lattice import b20_fege
    from repro.md.neighbor import dense_neighbor_table
    from repro.md.state import init_state
    lat = b20_fege()
    st = init_state(lat, (4, 4, 4), temperature=300.0,
                    key=jax.random.PRNGKey(0), dtype=jnp.float32)
    spec = NEPSpinSpec()
    params = init_params(spec, jax.random.PRNGKey(1), dtype=jnp.float32)
    tab = dense_neighbor_table(st.pos, st.box, spec.cutoff, 64)
    fn = jax.jit(lambda p, s: energy_forces_field(
        spec, params, p, s, st.types, tab, st.box))
    t = timeit(fn, st.pos, st.spin)
    return [row("kernels/nep-fused-force", t * 1e6,
                f"{st.n_atoms/t:.3e} atom/s")]


def main() -> list[str]:
    return bench_nep() + bench_attention() + bench_ssd()


if __name__ == "__main__":
    main()
