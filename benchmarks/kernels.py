"""Kernel microbenchmarks: fused vs reference implementations.

The NEP rows time the fused kernel pipeline stage by stage (K1
descriptor+ANN+adjoints, the abar_j adjoint gather, K2 pair force/torque)
through the mode-dispatched executor (``"auto"``: compiled Pallas on
TPU/GPU, the compiled lax.map tiling on CPU), with jaxpr-level FLOPs and
bytes per stage (repro.utils.jaxpr_cost) in the derived column - so both
wall-clock AND op-count regressions of any single stage are visible.
Attention/SSD rows time the *jnp* algorithmic variants (their Pallas
kernels remain interpret-validated only).

CSV: name, us_per_call, derived.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit


def bench_attention() -> list[str]:
    from repro.models.attention import chunked_attention
    from repro.kernels.attention.ref import attention_ref
    rows = []
    b, s, h, hkv, d = 1, 2048, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))

    naive = jax.jit(lambda q, k, v: attention_ref(
        q.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d),
        v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)))
    flash = jax.jit(lambda q, k, v: chunked_attention(q, k, v, pos, pos,
                                                      kv_chunk=512))
    t0 = timeit(naive, q, k, v)
    t1 = timeit(flash, q, k, v)
    flops = 4 * b * h * s * s * d
    rows.append(row("kernels/attention-naive", t0 * 1e6,
                    f"{flops/t0/1e9:.1f}GFLOP/s"))
    rows.append(row("kernels/attention-flash-chunked", t1 * 1e6,
                    f"{flops/t1/1e9:.1f}GFLOP/s|{t0/t1:.2f}x"))
    return rows


def bench_ssd() -> list[str]:
    from repro.models.ssm import ssd_chunked, ssd_reference
    rows = []
    bs, s, h, p, g, n, chunk = 1, 2048, 8, 32, 1, 32, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (bs, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b = jax.random.normal(ks[3], (bs, s, g, n)) * 0.3
    c = jax.random.normal(ks[4], (bs, s, g, n)) * 0.3
    dsk = jnp.ones((h,))
    rec = jax.jit(lambda *a_: ssd_reference(*a_))
    chu = jax.jit(lambda *a_: ssd_chunked(*a_, chunk))
    t0 = timeit(rec, x, dt, a, b, c, dsk)
    t1 = timeit(chu, x, dt, a, b, c, dsk)
    rows.append(row("kernels/ssd-recurrence", t0 * 1e6, "1.00x"))
    rows.append(row("kernels/ssd-chunked", t1 * 1e6, f"{t0/t1:.2f}x"))
    return rows


def bench_nep() -> list[str]:
    """Fused NEP force pipeline, stage by stage (the paper's hot kernel)."""
    from functools import partial

    from repro.core.descriptor import NEPSpinSpec
    from repro.core.potential import energy_forces_field, init_params
    from repro.kernels.nep import resolve_mode
    from repro.kernels.nep.kernel import (TILE_ATOMS, nep_atom_pass,
                                          nep_force_pass)
    from repro.kernels.nep.ops import _pad_to, nep_energy_forces_field
    from repro.launch.roofline import nep_measured
    from repro.md.lattice import b20_fege
    from repro.md.neighbor import dense_neighbor_table, gather_blocks
    from repro.md.state import init_state
    lat = b20_fege()
    st = init_state(lat, (4, 4, 4), temperature=300.0,
                    key=jax.random.PRNGKey(0), dtype=jnp.float32)
    spec = NEPSpinSpec()
    params = init_params(spec, jax.random.PRNGKey(1), dtype=jnp.float32)
    tab = dense_neighbor_table(st.pos, st.box, spec.cutoff, 64)
    mode = resolve_mode("auto")
    rows = []

    # whole-evaluation reference points: autodiff vs the fused kernel path
    ad = jax.jit(lambda p, s: energy_forces_field(
        spec, params, p, s, st.types, tab, st.box))
    t_ad = timeit(ad, st.pos, st.spin)
    rows.append(row("kernels/nep-autodiff-force", t_ad * 1e6,
                    f"{st.n_atoms/t_ad:.3e} atom/s"))
    kf = jax.jit(lambda p, s: nep_energy_forces_field(
        spec, params, p, s, st.types, tab, st.box, mode=mode))
    t_k = timeit(kf, st.pos, st.spin)
    rows.append(row(f"kernels/nep-fused-force/{mode}", t_k * 1e6,
                    f"{st.n_atoms/t_k:.3e} atom/s|{t_ad/t_k:.2f}x"))

    # stage micro-rows: K1 / abar_j gather / K2 at the same geometry, each
    # with its jaxpr-walked FLOPs + anchor bytes so op-count regressions
    # (e.g. a K2 that re-runs accumulate per pair) are visible per stage
    nbh = gather_blocks(st.pos, st.types, tab, st.box)
    n = st.n_atoms
    n_pad = -(-n // TILE_ATOMS) * TILE_ATOMS
    a = {
        "dr": _pad_to(nbh.dr, n_pad), "mask": _pad_to(nbh.mask, n_pad),
        "amask": _pad_to(jnp.ones((n,), bool), n_pad),
        "ti": _pad_to(st.types, n_pad), "tj": _pad_to(nbh.tj, n_pad),
        "si": _pad_to(st.spin, n_pad),
        "sj": _pad_to(st.spin[nbh.idx], n_pad),
        "idx": _pad_to(nbh.idx, n_pad),
    }
    cost = nep_measured(spec, params, nbh, st.spin, st.types, mode=mode)

    k1 = jax.jit(partial(nep_atom_pass, spec, params, mode=mode))
    t1 = timeit(k1, a["dr"], a["mask"], a["amask"], a["ti"], a["tj"],
                a["si"], a["sj"])
    _, _, abar = k1(a["dr"], a["mask"], a["amask"], a["ti"], a["tj"],
                    a["si"], a["sj"])
    gather = jax.jit(lambda ab, ix: {k: v[ix] for k, v in ab.items()})
    tg = timeit(gather, abar, a["idx"])
    abar_j = gather(abar, a["idx"])
    k2 = jax.jit(partial(nep_force_pass, spec, params, mode=mode))
    t2 = timeit(k2, a["dr"], a["mask"], a["ti"], a["tj"], a["si"], a["sj"],
                abar, abar_j)

    for name, t, c in (("k1-atom-pass", t1, cost["k1"]),
                       ("adjoint-gather", tg, cost["gather"]),
                       ("k2-force-pass", t2, cost["k2"])):
        rows.append(row(
            f"kernels/nep-{name}/{mode}", t * 1e6,
            f"{c['flops']:.3e}flop|{c['bytes_anchor']:.3e}B|"
            f"{c['flops']/t/1e9:.1f}GFLOP/s"))
    return rows


def main() -> list[str]:
    return bench_nep() + bench_attention() + bench_ssd()


if __name__ == "__main__":
    main()
