"""Paper Fig. 5 analogue: single-node optimization ablation.

The paper's five ARM-specific steps map onto the TPU/JAX pipeline as
structural variants of the force evaluation (DESIGN.md table); we measure
the same *algorithmic* deltas on this host:

  unfused-3pass   three independent neighbor traversals (energy, forces,
                  torques as separate autodiff calls) - the original
                  NEPSPIN baseline the paper starts from
  fused-autodiff  ONE traversal: value_and_grad over both R and S
                  (paper step 1, spin-radial force fusion)
  fused-2pass     explicit adjoint-accumulator two-pass scheme (the Pallas
                  kernel algorithm in pure jnp: K1 descriptor+ANN+adjoints,
                  K2 pair-symmetric forces - paper steps 2+5 structure)
  pruned-M        Phase-A pre-staging: neighbor table pruned to the exact
                  max coordination instead of a loose capacity
                  (paper step 2, SVE2 pre-staging)

CSV: name, us_per_call, derived=speedup-vs-unfused.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core.descriptor import NEPSpinSpec
from repro.core.potential import energy, energy_forces_field, init_params
from repro.md.lattice import b20_fege
from repro.md.neighbor import dense_neighbor_table
from repro.md.state import init_state


def _fused_2pass(spec, params, pos, spin, types, table, box):
    """jnp rendering of the kernel two-pass algorithm."""
    from repro.core.descriptor import (accumulate, finalize,
                                       init_accumulators)
    from repro.core.potential import mlp_energy
    from repro.md.neighbor import gather_neighbors

    dr, dist, sj, tj, mask = gather_neighbors(pos, spin, types, table, box)
    dp = params.desc_params()

    def f1(dr_, si_, sj_):
        acc = init_accumulators(spec, (pos.shape[0],), pos.dtype)
        acc = accumulate(spec, dp, acc, dr_, dist, mask, types, tj, si_,
                         sj_)
        q = finalize(spec, acc, si_)
        return jnp.sum(mlp_energy(params, q, types))

    e, grads = jax.value_and_grad(f1, argnums=(0, 1, 2))(dr, spin, sj)
    g_dr, g_si, g_sj = grads
    # pair-symmetric combine: F_i = sum_j g_dr[i,j] - gathered g_dr[j, slot]
    # (approximated here by the symmetric sum; exactness tested in kernels)
    f = jnp.sum(g_dr, axis=1)
    f = f - jnp.zeros_like(f)  # fold-back handled by gather in kernel path
    h = -(g_si + jnp.zeros_like(g_si))
    return e, f, h


def main() -> list[str]:
    lat = b20_fege()
    st = init_state(lat, (6, 6, 6), temperature=300.0,
                    key=jax.random.PRNGKey(0))
    spec = NEPSpinSpec()
    params = init_params(spec, jax.random.PRNGKey(1), dtype=jnp.float32)
    st = st._replace(pos=st.pos.astype(jnp.float32),
                     spin=st.spin.astype(jnp.float32))
    tab_loose = dense_neighbor_table(st.pos, st.box, spec.cutoff, 96)
    max_coord = int(tab_loose.mask.sum(1).max())
    tab_tight = dense_neighbor_table(st.pos, st.box, spec.cutoff,
                                     max_coord)

    @jax.jit
    def unfused(pos, spin):
        e = energy(spec, params, pos, spin, st.types, tab_loose, st.box)
        f = -jax.grad(lambda p: energy(spec, params, p, spin, st.types,
                                       tab_loose, st.box))(pos)
        h = -jax.grad(lambda s: energy(spec, params, pos, s, st.types,
                                       tab_loose, st.box))(spin)
        return e, f, h

    @jax.jit
    def fused(pos, spin):
        return energy_forces_field(spec, params, pos, spin, st.types,
                                   tab_loose, st.box)

    @jax.jit
    def fused2(pos, spin):
        return _fused_2pass(spec, params, pos, spin, st.types, tab_loose,
                            st.box)

    @jax.jit
    def pruned(pos, spin):
        return energy_forces_field(spec, params, pos, spin, st.types,
                                   tab_tight, st.box)

    t0 = timeit(unfused, st.pos, st.spin)
    rows = [row("ablation/unfused-3pass", t0 * 1e6, "1.00x")]
    for name, fn in (("fused-autodiff", fused), ("fused-2pass", fused2),
                     ("pruned-M", pruned)):
        t = timeit(fn, st.pos, st.spin)
        rows.append(row(f"ablation/{name}", t * 1e6, f"{t0/t:.2f}x"))
    rows.append(row("ablation/max_coordination", max_coord,
                    f"capacity96->{max_coord}"))
    return rows


if __name__ == "__main__":
    main()
