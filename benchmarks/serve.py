"""Serving-tier throughput: packed drain, WAL overhead, recovery replay.

The PR-9 tracked numbers for the crash-safe batched job server
(:mod:`repro.serve`):

* ``serve/drain`` - submit + drain a deterministic mixed fleet
  (``repro.launch.serve.build_fleet``: two shape buckets, two tenants,
  four protocol shapes) through a 2-slot server; jobs/s and slot-step/s,
  with the compile watchdog split (warmup vs steady) from the accounting
  ledger - steady-state recompiles must stay 0.
* ``serve/journal`` - the SAME fleet drained with the durable job
  journal (WAL) enabled; the derived column is the journal overhead %
  vs the plain drain (append-only JSONL at job-lifecycle + chunk-commit
  granularity, so it should stay in the noise).
* ``serve/recover`` - a journaled fleet is abandoned mid-flight after
  two scheduler ticks; ``SimServer.recover`` replays the WAL and the
  fleet is resubmitted (completed jobs deduplicate, interrupted jobs
  adopt their committed watermark).  us_per_call is the replay+resubmit
  latency - pure journal replay and queue reconstruction, no engine
  compute - and the drain that follows must close the accounting
  invariant with zero steady recompiles.

Emits ``BENCH_serve.json`` (repo root, full runs only) via
``benchmarks.common.write_json`` so the serving perf trajectory is
provenance-stamped.  CSV: name, us_per_call(=us/job; us/replay for
recover), derived as above.
"""
from __future__ import annotations

import os
import tempfile
import time

from benchmarks.common import SMOKE, row
from repro.launch.serve import build_fleet
from repro.serve import ServeConfig, SimServer

N_JOBS = 4 if SMOKE else 8
CHUNK = 10
OBS_EVERY = 5
SLOTS = 2


def _cfg(tmp: str, name: str, *, journal: bool = False) -> ServeConfig:
    return ServeConfig(
        runlog=os.path.join(tmp, f"{name}.jsonl"),
        workdir=os.path.join(tmp, name),
        journal_dir=os.path.join(tmp, f"{name}-journal") if journal
        else None,
        slots=SLOTS, chunk=CHUNK)


def _fleet():
    return build_fleet(N_JOBS, CHUNK, OBS_EVERY)


def _drain(cfg: ServeConfig) -> tuple[float, "SimServer"]:
    """(submit+drain wall s, drained server)."""
    srv = SimServer(cfg)
    t0 = time.perf_counter()
    handles = [srv.submit(job) for job in _fleet()]
    srv.drain()
    wall = time.perf_counter() - t0
    assert all(h.status == "done" for h in handles), \
        [(h.id, h.status, h.error) for h in handles]
    return wall, srv


def _compile_split(acct) -> tuple[int, int]:
    warm = sum(b["warmup_compiles"] for b in acct.buckets.values())
    steady = sum(b["steady_compiles"] for b in acct.buckets.values())
    return warm, steady


def main() -> list[str]:
    tmp = tempfile.mkdtemp(prefix="bench-serve-")
    total_steps = sum(j.steps for j in _fleet())
    rows = []
    out = {"smoke": SMOKE, "n_jobs": N_JOBS, "slots": SLOTS,
           "chunk": CHUNK, "total_slot_steps": total_steps}

    # throwaway drain so the timed runs don't pay process-wide jax init
    # or cold XLA-cache compiles (each server builds fresh engines, but
    # the in-process compilation cache dedupes identical chunk HLO)
    _drain(_cfg(tmp, "warmup"))

    # --- packed drain: jobs/s + compile watchdog ----------------------
    wall, srv = _drain(_cfg(tmp, "plain"))
    acct = srv.accounting
    warm, steady = _compile_split(acct)
    assert acct.consistent(), acct.summary()
    out["drain"] = {"wall_s": wall, "jobs_per_s": N_JOBS / wall,
                    "slot_steps_per_s": total_steps / wall,
                    "warmup_compiles": warm, "steady_compiles": steady}
    rows.append(row(
        f"serve/drain/J={N_JOBS}", wall * 1e6 / N_JOBS,
        f"{N_JOBS / wall:.2f} jobs/s|"
        f"{total_steps / wall:.3e} slot-step/s|"
        f"{warm} warmup/{steady} steady compiles"))

    # --- the same fleet with the WAL on: journal overhead % -----------
    wall_j, srv_j = _drain(_cfg(tmp, "wal", journal=True))
    assert srv_j.accounting.consistent(), srv_j.accounting.summary()
    overhead = (wall_j / wall - 1.0) * 100.0
    out["journal"] = {"wall_s": wall_j, "overhead_pct": overhead}
    rows.append(row(
        f"serve/journal/J={N_JOBS}", wall_j * 1e6 / N_JOBS,
        f"journal overhead {overhead:+.1f}% vs plain drain"))

    # --- recovery replay: abandon mid-flight, replay the WAL ----------
    cfg_r = _cfg(tmp, "rec", journal=True)
    srv_r = SimServer(cfg_r)
    for job in _fleet():
        srv_r.submit(job)
    for _ in range(2):          # two committed chunks per bucket, then die
        srv_r._tick()
    del srv_r

    t0 = time.perf_counter()
    srv2 = SimServer.recover(cfg_r)
    handles = [srv2.submit(job) for job in _fleet()]
    replay = time.perf_counter() - t0
    deduped = sum(h.status == "done" for h in handles)
    resumed = sum(h.rows_base > 0 for h in handles)
    srv2.drain()
    acct2 = srv2.accounting
    _, steady2 = _compile_split(acct2)
    assert acct2.consistent(), acct2.summary()
    assert all(h.status == "done" for h in handles), \
        [(h.id, h.status, h.error) for h in handles]
    out["recovery"] = {"replay_s": replay, "deduplicated": deduped,
                       "resumed": resumed, "steady_compiles": steady2}
    rows.append(row(
        f"serve/recover/J={N_JOBS}", replay * 1e6,
        f"{deduped} dedup|{resumed} resumed|"
        f"{steady2} steady compiles after recovery"))

    if not SMOKE:
        # acceptance: the compiled chunks never retrace in steady state,
        # in either the plain drain or the recovered incarnation
        assert steady == 0, out["drain"]
        assert steady2 == 0, out["recovery"]
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        from benchmarks.common import write_json
        write_json(os.path.join(root, "BENCH_serve.json"), out)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
