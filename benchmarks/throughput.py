"""Paper Fig. 6 / Table I analogue: throughput scaling with system size.

Measures atom-step/s of the whole coupled spin-lattice application
(neighbor gather + NEP-SPIN inference + integrator + thermostats) across
system sizes on this host, verifying the O(N) scaling that underpins the
paper's trillion-atom extrapolation, and derives s/step/atom (the paper's
TtS metric) + normalized TtS per model parameter.

CSV: name, us_per_call(=us/step), derived=atom-step/s|s/step/atom.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core.descriptor import NEPSpinSpec
from repro.core.potential import init_params
from repro.md.integrator import ForceField, IntegratorConfig, make_step
from repro.md.lattice import b20_fege
from repro.md.neighbor import dense_neighbor_table
from repro.md.state import init_state
from repro.utils.tree import tree_count


def main() -> list[str]:
    lat = b20_fege()
    spec = NEPSpinSpec(l_max=2, n_ang=2, n_rad=4, n_spin=2, basis_size=6)
    params = init_params(spec, jax.random.PRNGKey(0), dtype=jnp.float32)
    n_param = tree_count(params)
    icfg = IntegratorConfig(dt=1e-3, temperature=160.0, lattice_gamma=1.0,
                            spin_alpha=0.05)
    masses = jnp.asarray(lat.masses, jnp.float32)
    magnetic = jnp.asarray(lat.moments) > 0

    rows = []
    for cells in (3, 4, 6, 8):
        st = init_state(lat, (cells,) * 3, temperature=160.0,
                        key=jax.random.PRNGKey(1), dtype=jnp.float32)
        n = st.n_atoms
        tab = dense_neighbor_table(st.pos, st.box, spec.cutoff, 64)

        def evaluate(pos, spin, tab=tab, types=st.types, box=st.box):
            from repro.core.potential import energy_forces_field
            return ForceField(*energy_forces_field(
                spec, params, pos, spin, types, tab, box))

        step = make_step(evaluate, icfg, masses, magnetic)

        @jax.jit
        def do_step(state, ff, key):
            return step(state, ff, key)

        ff = evaluate(st.pos, st.spin)
        t = timeit(lambda: do_step(st, ff, jax.random.PRNGKey(2)))
        atom_step_s = n / t
        rows.append(row(f"throughput/N={n}", t * 1e6,
                        f"{atom_step_s:.3e} atom-step/s|"
                        f"{t/n:.3e} s/step/atom|"
                        f"{t/n/n_param:.3e} s/(atom*param*step)"))
    # O(N) check: TtS/atom between smallest and largest within 2x
    return rows


if __name__ == "__main__":
    main()
