"""Ensemble-engine throughput: vmapped replicas vs a sequential loop.

Measures replica-step/s of the vmapped multi-replica chunk (one compiled
scan serving R replicas under a temperature ramp) against R sequential
single-replica chunks over the same Hamiltonian - the batching win that
makes ensemble scenario sweeps (Fig. 9 nucleation statistics, (T, B) phase
maps) affordable.  Also reports the phase-diagram aggregate rate.

CSV: name, us_per_call(=us/chunk), derived=atom-step/s|speedup.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core.hamiltonian import HeisenbergDMIModel
from repro.ensemble import protocol
from repro.ensemble.replica import ReplicaEnsemble, replicate
from repro.md.integrator import IntegratorConfig
from repro.md.lattice import simple_cubic
from repro.md.state import init_state

CHUNK = 50


def _ensemble(n_replicas: int, cells=(16, 16, 1)):
    lat = simple_cubic()
    ham = HeisenbergDMIModel(d0=0.01)
    st = init_state(lat, cells, spin_init="helix_x",
                    key=jax.random.PRNGKey(0), dtype=jnp.float32)
    cfg = IntegratorConfig(dt=2e-3, lattice_gamma=2.0, spin_alpha=0.1)
    ens = ReplicaEnsemble(
        potential=ham, cfg=cfg, states=replicate(st, n_replicas),
        masses=jnp.asarray(lat.masses, jnp.float32),
        magnetic=jnp.asarray(lat.moments) > 0,
        cutoff=5.0, capacity=8, diag_grid=(16, 16), pitch_bins=16)
    temp = protocol.linear(0.0, CHUNK * cfg.dt, 95.0, 20.0)
    fld = protocol.constant(jnp.asarray([0.0, 0.0, 25.0]))
    return ens, temp, fld, st.n_atoms


def main() -> list[str]:
    rows = []
    base_t = None
    for n_rep in (1, 4, 16):
        ens, temp, fld, n_atoms = _ensemble(n_rep)
        # one compiled engine chunk: R replicas, schedules evaluated
        # in-scan, per-chunk observables reduced in-graph
        eng = ens._engine
        targ = eng._norm_arg(temp, vec=False)
        farg = eng._norm_arg(fld, vec=True)

        def do_chunk(key):
            return eng._chunk_fn(eng._carry, key, targ, farg, CHUNK, None)

        t = timeit(lambda: do_chunk(jax.random.PRNGKey(1)),
                   warmup=1, iters=3)
        rate = n_rep * n_atoms * CHUNK / t
        if base_t is None:
            base_t = t  # R=1 chunk time
        speedup = base_t * n_rep / t  # vs R sequential single-replica chunks
        rows.append(row(f"ensemble/R={n_rep}", t * 1e6,
                        f"{rate:.3e} atom-step/s|"
                        f"{speedup:.2f}x vs sequential"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
