"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import time

import jax

# smoke mode (scripts/ci.sh --smoke): every benchmark runs 1 iteration on
# downscaled problems - enough to catch bit-rotted perf code, not to time it
SMOKE = bool(os.environ.get("BENCH_SMOKE"))


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call [s], after jit warmup."""
    if SMOKE:
        warmup, iters = 0, 1
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line


def provenance() -> dict:
    """Environment stamp for emitted BENCH_*.json: perf numbers are only
    comparable across PRs when the jax version / backend / device count
    match."""
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "host_cores": os.cpu_count() or 1,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }


def write_json(path: str, payload: dict) -> str:
    """Write a BENCH_*.json with the provenance stamp attached."""
    payload = dict(payload)
    payload["provenance"] = provenance()
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {os.path.basename(path)}", flush=True)
    return path
