"""Shared benchmark utilities."""
from __future__ import annotations

import os
import time

import jax

# smoke mode (scripts/ci.sh --smoke): every benchmark runs 1 iteration on
# downscaled problems - enough to catch bit-rotted perf code, not to time it
SMOKE = bool(os.environ.get("BENCH_SMOKE"))


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call [s], after jit warmup."""
    if SMOKE:
        warmup, iters = 0, 1
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line
