"""Paper Fig. 7/8 + Table V analogue: weak & strong scaling projections.

No multi-node hardware exists here, so scaling curves are DERIVED from the
dry-run artifacts the same way the roofline is: per-device compute time is
the dominant roofline term of the compiled step, and communication is the
halo volume (MD: one ghost-cell layer per face = O(N_local^{2/3})) over the
ICI/DCN bandwidth.  This reproduces the paper's weak-scaling-efficiency
structure (small case less comm-amortized than large) and the strong-
scaling efficiency droop as per-device work shrinks.

CSV: name, us_per_call(=modelled step us), derived=efficiency.
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from benchmarks.common import row
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

# per-chip MD cost model extracted from the dry-run records
_DRYRUN_GLOB = os.path.join("experiments", "dryrun",
                            "fege-spinlattice__md_{case}__pod1.json")


def _load(case):
    path = _DRYRUN_GLOB.format(case=case)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _md_step_time(flops_dev, atoms_dev, cells_per_dev, ici_bw=ICI_BW):
    """(compute_s, comm_s): halo = 6 faces x cell layer x state payload."""
    compute = flops_dev / PEAK_FLOPS
    face_cells = 6 * cells_per_dev ** 2
    payload = face_cells * 16 * (3 + 3 + 1 + 1) * 4   # pos+spin+type+id f32
    comm = payload / ici_bw
    return compute, comm


def weak_scaling() -> list[str]:
    rows = []
    for case, cells in (("small", 8), ("large", 16)):
        rec = _load(case)
        if rec is None:
            continue
        flops_dev = rec["flops_total"]
        atoms_dev = rec["meta"]["atoms_per_device"]
        comp, comm = _md_step_time(flops_dev, atoms_dev, cells)
        t1 = comp  # single chip: no halo cost
        for chips in (1, 16, 256, 512, 4096, 20480):
            # cross-pod halo crosses DCN (~5x slower) beyond 256 chips
            scale = 1.0 if chips <= 256 else 5.0
            tn = comp + comm * scale * (0.0 if chips == 1 else 1.0)
            eff = t1 / tn
            rows.append(row(
                f"weak/{case}/chips={chips}", tn * 1e6,
                f"eff={eff*100:.1f}%|atoms={atoms_dev*chips:.2e}"))
    return rows


def strong_scaling() -> list[str]:
    """Fixed global system, chips swept: per-chip work shrinks, halo
    surface/volume ratio grows (paper Table V structure)."""
    rows = []
    rec = _load("large")
    if rec is None:
        return rows
    flops_dev0 = rec["flops_total"]
    cells0 = 16
    base_chips = 512
    total_flops = flops_dev0 * base_chips
    t_base = None
    for chips in (512, 1024, 2048, 4096, 8192):
        flops_dev = total_flops / chips
        cells = cells0 * (base_chips / chips) ** (1 / 3)
        comp, comm = _md_step_time(flops_dev, None, cells)
        tn = comp + comm * 5.0
        if t_base is None:
            t_base = tn
        speedup = t_base / tn
        ideal = chips / 512
        rows.append(row(f"strong/268B-analogue/chips={chips}", tn * 1e6,
                        f"speedup={speedup:.2f}x|"
                        f"eff={speedup/ideal*100:.1f}%"))
    return rows


def main() -> list[str]:
    return weak_scaling() + strong_scaling()


if __name__ == "__main__":
    main()
