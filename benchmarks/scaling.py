"""Weak scaling of the SHARDED fused MD loop (paper Fig. 7 analogue).

Unlike the projection-only predecessor, this drives the real thing
end-to-end: :class:`repro.md.simulate.SimulationSharded` - the shard_map
domain-decomposed fused loop (in-scan rebuild + cell migration, one
position halo per drift, adjoint-halo force fold-back) - on 1/2/4/8
*simulated* host devices (``XLA_FLAGS=--xla_force_host_platform_device_
count=N``), with a fixed per-device subdomain (weak scaling).

Each device count runs in its OWN subprocess (the forced device count must
be set before jax initializes); the parent collects per-worker JSON and
emits ``BENCH_scaling.json`` with

* steps/s and weak-scaling efficiency vs the 1-device *flat* fused
  baseline (``Simulation`` at the same per-device atom count),
* per-step halo traffic by tag (position drift / spin / adjoint fold-back)
  from the run-scoped trace-time exchange ledger
  (``SimulationSharded.halo_ledger``),
* recompile counts during the measured run (must be 0: one compiled chunk
  covers every in-scan rebuild + migration), and
* the drift-exchange invariant: exactly ONE position halo per drift,
  asserted from the traced step body.

Full (non-smoke) runs also record a ``nep_kernel`` entry: the fused
NEP-SPIN kernel evaluator (``use_kernel=True``, mode "auto": compiled
lax.map tiling on CPU, the identical bodies as MXU Pallas kernels on TPU)
routed through the SAME sharded loop via the q_Fp adjoint-accumulator halo
(``repro.parallel.domain.make_domain_kernel_evaluator``): steps/s on 2
devices plus the exchange ledger, tracked so the kernel path through the
domain decomposition can't silently rot.  On CPU the smoke-sized spec
times the orchestration, not the kernel - the numbers to watch are zero
recompiles and the expected exchange counts (the kernel-level speed gate
lives in benchmarks/md_loop.py: ``nep_kernel.vs_autodiff``).

Simulated devices share this host's cores, so wall-clock efficiency here
measures the *orchestration + communication overhead floor* of the sharded
loop, not multi-chip hardware scaling - the number every later multi-host
PR measures against.

CSV rows: name, us_per_call(=us/step), derived=steps/s|eff|rebuilds|comp.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

DEVICE_COUNTS = (1, 2, 4, 8)
SMOKE_DEVICES = (2,)
# per-device lattice supercells: "floor" is small enough that a step is
# dominated by fixed orchestration + collective latency (the overhead
# floor the acceptance gate tracks); "bulk" is compute-bound and shows the
# honest raw falloff when simulated devices oversubscribe the host cores
SIZES = {"floor": (4, 4, 4), "bulk": (8, 8, 8)}     # 64 / 512 atoms
CHUNK = 80
CUTOFF, SKIN, CAPACITY = 5.0, 0.3, 8
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# worker: runs under a forced device count, prints one RESULT json line
# ---------------------------------------------------------------------------

def _worker(ndev: int, size: str, smoke: bool) -> None:
    import jax
    import jax.numpy as jnp

    from repro.core.hamiltonian import HeisenbergDMIModel
    from repro.md.integrator import IntegratorConfig
    from repro.md.lattice import simple_cubic
    from repro.md.simulate import Simulation, SimulationSharded
    from repro.md.state import init_state

    assert len(jax.devices()) == ndev, (len(jax.devices()), ndev)
    steps = CHUNK if smoke else 3 * CHUNK

    compiles = {"n": 0}

    def on_event(name, _dur, **kw):
        if name == "/jax/core/compile/backend_compile_duration":
            compiles["n"] += 1

    jax.monitoring.register_event_duration_secs_listener(on_event)

    lat = simple_cubic()
    per_dev = SIZES[size]
    cells = (per_dev[0] * ndev,) + per_dev[1:]
    st = init_state(lat, cells, temperature=300.0, spin_init="helix_x",
                    key=jax.random.PRNGKey(0), dtype=jnp.float32)
    ham = HeisenbergDMIModel(d0=0.01)
    cfg = IntegratorConfig(dt=2e-3)
    masses = jnp.asarray(lat.masses, jnp.float32)
    magnetic = jnp.asarray(lat.moments) > 0
    kw = dict(potential=ham, cfg=cfg, masses=masses, magnetic=magnetic,
              cutoff=CUTOFF, capacity=CAPACITY, skin=SKIN)

    def timed(sim, warm_key, run_key):
        sim.run(CHUNK, warm_key, chunk=CHUNK)          # compile + warm
        jax.block_until_ready(sim.state.pos)
        c0 = compiles["n"]
        t0 = time.perf_counter()
        sim.run(steps, run_key, chunk=CHUNK)
        jax.block_until_ready(sim.state.pos)
        return (time.perf_counter() - t0, compiles["n"] - c0)

    out = {"ndev": ndev, "size": size, "atoms": st.n_atoms,
           "atoms_per_device": st.n_atoms // ndev, "steps": steps}

    if ndev == 1:
        flat = Simulation(state=st, **kw)
        wall, _ = timed(flat, jax.random.PRNGKey(1), jax.random.PRNGKey(2))
        out["flat_steps_per_s"] = steps / wall

    sh = SimulationSharded(state=st, **kw)
    wall, n_comp = timed(sh, jax.random.PRNGKey(1), jax.random.PRNGKey(2))
    # one traced chunk covers warmup AND the measured run: counts are
    # per-step-body occurrences, bytes are per-device per occurrence;
    # the run-scoped ledger sees only THIS simulation's exchanges
    ledger = sh.halo_ledger
    per_exchange = ledger.per_exchange_bytes()
    out.update({
        "steps_per_s": steps / wall,
        "wall_s": wall,
        "rebuilds": sh.n_rebuilds,
        "migrated": sh.n_migrated,
        "compiles_during_run": n_comp,
        "chunk_cache": len(sh._chunk_cache),
        "cells": sh._dspec.cells,
        "cell_capacity": sh._dspec.capacity,
        "drift_pos_exchanges_per_step": ledger.counts.get("drift-pos", 0),
        "halo_bytes_per_exchange": per_exchange,
        # per executed step: one drift-pos, one spin, one adjoint round
        "halo_bytes_per_step": ledger.per_step_bytes(),
    })
    # the drift-exchange invariant of the gather->compute contract
    assert out["drift_pos_exchanges_per_step"] == 1, ledger.counts
    print("RESULT " + json.dumps(out), flush=True)


def _worker_kernel(ndev: int, smoke: bool) -> None:
    """Pallas NEP kernel through the sharded loop (q_Fp halo route).

    Delegates to :func:`repro.launch.md_step.run_engine_chunk` - the same
    schedule-driven engine chunk the launch-surface smoke drives - so the
    benchmark and the human smoke cannot drift apart; this worker only
    adds the invariants and the RESULT line.
    """
    import jax

    from repro.launch.md_step import run_engine_chunk

    assert len(jax.devices()) == ndev, (len(jax.devices()), ndev)
    chunk = 2 if smoke else 5
    steps = chunk if smoke else 2 * chunk
    # y/z need >= 3 cells at cutoff+skin reach; x scales with the devices
    res = run_engine_chunk(cells=(4 * ndev, 6, 6), steps=steps,
                           chunk=chunk, kernel=True)
    counts = res.pop("halo_counts")
    res.pop("halo_bytes")
    out = {
        "ndev": ndev, "steps": steps, "mode": "auto", **res,
        "cells": list(res["cells"]),
        "drift_pos_exchanges_per_step": counts.get("drift-pos", 0),
        "qfp_exchanges": counts.get("qfp", 0),
        "halo_counts": counts,
    }
    # the kernel route's contract: one position halo per drift, and the
    # adjoint accumulators move through the q_Fp exchange (no fold)
    assert out["drift_pos_exchanges_per_step"] == 1, counts
    assert out["qfp_exchanges"] >= 1, counts
    assert "adjoint" not in counts, counts
    print("RESULT " + json.dumps(out), flush=True)


# ---------------------------------------------------------------------------
# parent: one subprocess per device count (XLA_FLAGS must precede jax init)
# ---------------------------------------------------------------------------

def _run_worker(ndev: int, size: str, smoke: bool,
                kernel: bool = False) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    if smoke:
        env["BENCH_SMOKE"] = "1"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "benchmarks.scaling", "--worker",
           str(ndev)] + (["--kernel"] if kernel
                         else ["--size", size])
    r = subprocess.run(cmd, env=env, cwd=_ROOT, capture_output=True,
                       text=True, timeout=3600)
    if r.returncode != 0:
        raise RuntimeError(
            f"scaling worker ndev={ndev} failed:\n{r.stderr[-4000:]}")
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT ")]
    return json.loads(line[0][len("RESULT "):])


def main() -> list[str]:
    from benchmarks.common import SMOKE, row

    rows = []
    counts = SMOKE_DEVICES if SMOKE else DEVICE_COUNTS
    sizes = ("floor",) if SMOKE else tuple(SIZES)
    cores = os.cpu_count() or 1
    out = {"smoke": SMOKE, "potential": "heisenberg", "chunk": CHUNK,
           "skin": SKIN, "capacity": CAPACITY, "host_cores": cores,
           "efficiency_definition": (
               "weak_efficiency = steps/s(n) / (steps/s(1 dev, sharded) * "
               "min(1, host_cores/n)): simulated devices share this "
               "host's cores, so the achievable ideal caps at cores/n of "
               "the 1-device rate; weak_efficiency_raw is the "
               "uncorrected steps/s(n) / steps/s(1).  The acceptance "
               "gate applies at the largest n <= host_cores; "
               "oversubscribed points are trend-only (their ideal "
               "assumes perfect VM time-slicing)"),
           "sizes": {}}
    for size in sizes:
        results = {n: _run_worker(n, size, SMOKE) for n in counts}
        base_sh = results.get(1, {}).get("steps_per_s")
        base_flat = results.get(1, {}).get("flat_steps_per_s")
        entry = {"atoms_per_device":
                 results[counts[0]]["atoms_per_device"],
                 "flat_1dev_steps_per_s": base_flat, "sharded": {}}
        for n, res in results.items():
            if base_sh:
                res["weak_efficiency_raw"] = res["steps_per_s"] / base_sh
                res["weak_efficiency"] = (
                    res["steps_per_s"] / (base_sh * min(1.0, cores / n)))
            eff = res.get("weak_efficiency")
            entry["sharded"][str(n)] = res
            rows.append(row(
                f"scaling/{size}/sharded/ndev={n}/N={res['atoms']}",
                1e6 / res["steps_per_s"],
                f"{res['steps_per_s']:.1f} steps/s|"
                + (f"eff={eff * 100:.1f}%|" if eff else "")
                + f"{res['rebuilds']} rebuilds|"
                f"{res['compiles_during_run']} compiles|"
                f"halo={res['halo_bytes_per_step']}B/step"))
        if base_flat:
            rows.append(row(f"scaling/{size}/baseline/flat-fused/ndev=1",
                            1e6 / base_flat, f"{base_flat:.1f} steps/s"))
        out["sizes"][size] = entry
    if not SMOKE:
        # the fused NEP kernel through the SAME sharded loop (q_Fp halo);
        # smoke-sized spec, so only orchestration invariants are asserted
        kres = _run_worker(2, "floor", SMOKE, kernel=True)
        out["nep_kernel"] = kres
        rows.append(row(
            f"scaling/nep_kernel/sharded/ndev=2/N={kres['atoms']}",
            1e6 / kres["steps_per_s"],
            f"{kres['steps_per_s']:.2f} steps/s|{kres['mode']}|"
            f"{kres['compiles_during_run']} compiles|"
            f"qfp={kres['qfp_exchanges']}"))
        assert kres["compiles_during_run"] == 0, kres
        # acceptance (on the overhead-floor size): the largest device
        # count that FITS the host cores must stay within 2x of ideal,
        # plus zero recompiles and one position halo per drift (asserted
        # in-worker).  Oversubscribed points (n > cores) are recorded for
        # trend only: their min(1, cores/n) "ideal" assumes perfect VM
        # time-slicing, so the ratio degrades whenever the per-step
        # compute gets faster while the fixed scheduling overhead of
        # n-VMs-on-fewer-cores does not - gating there would punish
        # hot-loop speedups.  (PR 4's 0.65 bound was recorded against a
        # load-depressed 1-device baseline; the PR 4 code measures
        # eff(2) ~ 0.57 on an idle 2-core host, PR 5 ~ 0.55 with ~10%
        # higher absolute steps/s at every point.)
        gate_n = max((n for n in counts if n <= cores), default=None)
        if gate_n is not None and gate_n > 1:
            gated = out["sizes"]["floor"]["sharded"][str(gate_n)]
            assert gated["weak_efficiency"] >= 0.5, gated
        out["efficiency_gate"] = {"ndev": gate_n, "min": 0.5}
        for size in sizes:
            for res in out["sizes"][size]["sharded"].values():
                assert res["compiles_during_run"] == 0, res
                assert res["chunk_cache"] == 1, res
        from benchmarks.common import write_json
        write_json(os.path.join(_ROOT, "BENCH_scaling.json"), out)
    return rows


if __name__ == "__main__":
    if "--worker" in sys.argv:
        ndev = int(sys.argv[sys.argv.index("--worker") + 1])
        smoke = bool(os.environ.get("BENCH_SMOKE"))
        if "--kernel" in sys.argv:
            _worker_kernel(ndev, smoke)
        else:
            size = (sys.argv[sys.argv.index("--size") + 1]
                    if "--size" in sys.argv else "floor")
            _worker(ndev, size, smoke)
    else:
        print("name,us_per_call,derived")
        main()
