"""CI smoke for the fused NEP kernel dispatch (scripts/ci.sh --smoke).

Fails fast if the kernel path regresses to interpret-mode dispatch or
loses parity:

* ``resolve_mode("auto")`` must pick a COMPILED executor on this backend
  (``"xla_tiled"`` on CPU - never ``"interpret"``);
* the compiled path must match the autodiff ref oracle on (E, F, H_eff)
  at f32 tolerance on an untruncated neighbor table (the pair-symmetric
  force formula assumes a symmetric list, so the table must not overflow);
* the compiled path must BEAT interpret-mode wall-clock on repeated
  warmed calls (median of 3) - the regression this smoke exists to catch
  turns a compiled executor back into the Python-stepped interpreter,
  which is a many-fold slowdown, so the 1.2x bar is loose but decisive;
* one warmed chunked sequence of calls must trigger ZERO further XLA
  backend compiles (the zero-recompile contract chunked drivers rely on).
"""
from __future__ import annotations

import statistics
import sys
import time

import jax
import jax.numpy as jnp


def main() -> None:
    from repro.core.descriptor import NEPSpinSpec
    from repro.core.potential import init_params
    from repro.kernels.nep import (nep_energy_forces_field,
                                   nep_energy_forces_field_ref, resolve_mode)
    from repro.md.lattice import b20_fege
    from repro.md.neighbor import dense_neighbor_table
    from repro.md.state import init_state

    mode = resolve_mode("auto")
    assert mode != "interpret", (
        f"auto dispatch resolved to interpret on {jax.default_backend()}")
    expect = "pallas" if jax.default_backend() in ("tpu", "gpu") else \
        "xla_tiled"
    assert mode == expect, (mode, expect)

    spec = NEPSpinSpec(l_max=2, n_ang=2, n_rad=4, n_spin=2, basis_size=6)
    st = init_state(b20_fege(), (4, 4, 4), temperature=300.0,
                    spin_init="random", key=jax.random.PRNGKey(0))
    st = st._replace(pos=st.pos + 0.08 * jax.random.normal(
        jax.random.PRNGKey(9), st.pos.shape, st.pos.dtype))
    params = init_params(spec, jax.random.PRNGKey(1), dtype=jnp.float32)
    tab = dense_neighbor_table(st.pos, st.box, spec.cutoff, 64)
    assert not bool(tab.mask.sum(1).max() >= 64), "table overflow"
    args = (spec, params, st.pos, st.spin, st.types, tab, st.box)

    ref = nep_energy_forces_field_ref(*args)
    out = nep_energy_forces_field(*args, mode=mode)
    for got, want, name, tol in zip(out, ref, ("E", "F", "H"),
                                    (1e-4, 2e-4, 2e-4)):
        got, want = jnp.asarray(got), jnp.asarray(want)
        rel = float(jnp.max(jnp.abs(got - want))
                    / (jnp.max(jnp.abs(want)) + 1e-30))
        assert rel < tol, f"{name} parity: rel={rel:.3e} >= {tol}"
        print(f"parity {name}: rel={rel:.3e}")

    def med_time(m: str) -> float:
        r = nep_energy_forces_field(*args, mode=m)   # warmup compile
        jax.block_until_ready(r)
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(5):
                r = nep_energy_forces_field(*args, mode=m)
            jax.block_until_ready(r)
            ts.append((time.perf_counter() - t0) / 5)
        return statistics.median(ts)

    t_fast = med_time(mode)
    t_interp = med_time("interpret")
    ratio = t_interp / t_fast
    print(f"{mode}: {t_fast*1e3:.2f} ms/call, interpret: "
          f"{t_interp*1e3:.2f} ms/call ({ratio:.2f}x)")
    assert ratio > 1.2, (
        f"compiled mode {mode} only {ratio:.2f}x vs interpret - dispatch "
        f"regression?")

    # zero-recompile contract: chunked re-evaluation at fixed geometry.
    # Warm with a COMPUTED position array first - computed outputs are
    # committed to a device while init_state's arrays are not, and the
    # commitment bit is part of the jit cache key (one legitimate extra
    # entry, not a per-chunk retrace).
    r = nep_energy_forces_field(spec, params, st.pos + 0.0, st.spin,
                                st.types, tab, st.box, mode=mode)
    jax.block_until_ready(r)
    compiles = {"n": 0}

    def on_event(name, _dur, **kw):
        if name == "/jax/core/compile/backend_compile_duration":
            compiles["n"] += 1

    jax.monitoring.register_event_duration_secs_listener(on_event)
    for i in range(4):
        r = nep_energy_forces_field(
            spec, params, st.pos + 1e-4 * i, st.spin, st.types, tab,
            st.box, mode=mode)
    jax.block_until_ready(r)
    assert compiles["n"] == 0, f"{compiles['n']} recompiles across chunks"
    print(f"kernel smoke OK: mode={mode}, {ratio:.2f}x vs interpret, "
          f"0 recompiles")


if __name__ == "__main__":
    sys.exit(main())
