#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md). Usage: scripts/ci.sh [pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

# install prerequisites only when missing (the CI image bakes them in)
python - <<'EOF' || pip install -r requirements.txt
import importlib.util as u, sys
sys.exit(0 if all(u.find_spec(m) for m in
                  ("jax", "numpy", "pytest", "hypothesis")) else 1)
EOF

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
