#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md).
# Usage: scripts/ci.sh [pytest args]   - run the tier-1 test suite
#        scripts/ci.sh --smoke         - 1-iteration benchmark smoke run
#                                        (every benchmarks/ module executes
#                                        on downscaled problems, so perf
#                                        code can't silently rot; CI FAILS
#                                        if any module crashes).  This
#                                        includes benchmarks/scaling.py,
#                                        which spawns a 2-simulated-device
#                                        subprocess so the shard_map domain
#                                        loop compiles in CI, and the
#                                        2-device ENGINE smoke: one
#                                        schedule-driven sharded chunk plus
#                                        a checkpoint/resume cycle asserted
#                                        bitwise (scripts/engine_smoke.py).
#                                        The engine smoke also asserts the
#                                        telemetry contract: the runlog
#                                        JSONL has >=1 chunk record whose
#                                        halo bytes match the run-scoped
#                                        ledger, compile count is 0 after
#                                        warmup, energy drift + health
#                                        verdict are present, and
#                                        `python -m repro.launch.report`
#                                        renders it without error.  Also
#                                        the resilience smoke
#                                        (scripts/resilience_smoke.py):
#                                        a supervised seeded-NaN
#                                        rollback-retry asserted bitwise
#                                        with zero retry recompiles, and
#                                        a SIGKILL kill-and-resume cycle
#                                        (<= 1 chunk lost, bitwise).
#                                        Plus the serving smoke
#                                        (scripts/serve_smoke.py): a
#                                        mixed fleet through the batched
#                                        job server at f64 with bitwise
#                                        packed-vs-solo parity, the
#                                        serve chaos smoke
#                                        (scripts/serve_chaos_smoke.py):
#                                        a seeded NaN/bit-flip/SIGKILL
#                                        campaign through the serving
#                                        tier with WAL recovery asserted
#                                        bitwise at f64, the NEP kernel
#                                        smoke (scripts/kernel_smoke.py):
#                                        auto dispatch must resolve to a
#                                        compiled executor (xla_tiled on
#                                        CPU), match the autodiff oracle,
#                                        beat interpret wall-clock, and
#                                        recompile zero times across
#                                        chunked calls, and the docs
#                                        link check
#                                        (scripts/check_docs.py).
#                                        The benchmark pass runs --strict:
#                                        perf-regression warnings become
#                                        failures (md_loop hard-fails if
#                                        kernel dispatch is interpret).
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--smoke" ]]; then
  env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
      XLA_FLAGS="--xla_force_host_platform_device_count=2" \
      python scripts/engine_smoke.py
  env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
      python scripts/resilience_smoke.py
  # serving smoke: >=6 mixed-size jobs over >=2 shape buckets at f64 -
  # zero steady-state recompiles, packed-vs-solo bitwise parity, and a
  # consistent per-tenant accounting ledger (scripts/serve_smoke.py)
  env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
      python scripts/serve_smoke.py
  # serve chaos smoke: a child server dies by SIGKILL mid-fleet under a
  # seeded fault plan; the parent recovers from the durable job journal
  # and proves the remaining streams bitwise with zero steady recompiles
  env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
      python scripts/serve_chaos_smoke.py
  # NEP kernel smoke: compiled dispatch (never interpret), oracle parity,
  # faster-than-interpret, and zero recompiles across chunked calls
  env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
      python scripts/kernel_smoke.py
  # docs must not reference files that no longer exist
  python scripts/check_docs.py
  exec env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" BENCH_SMOKE=1 \
      python -m benchmarks.run --smoke --strict
fi

# install prerequisites only when missing (the CI image bakes them in)
python - <<'EOF' || pip install -r requirements.txt
import importlib.util as u, sys
sys.exit(0 if all(u.find_spec(m) for m in
                  ("jax", "numpy", "pytest", "hypothesis")) else 1)
EOF

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
