"""Docs link check: every repo path referenced from ``docs/*.md`` must
exist.

Scans the markdown under ``docs/`` for references that look like repo
paths (``src/...``, ``scripts/...``, ``tests/...``, ``benchmarks/...``,
``docs/...`` - bare or inside backticks/links) and exits nonzero listing
any that no longer point at a real file or directory.  Wired into
``scripts/ci.sh --smoke`` so renames that orphan the documentation fail
CI instead of rotting silently.

    python scripts/check_docs.py
"""
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = os.path.join(ROOT, "docs")

# repo-relative paths: a known top-level dir, then /-separated
# identifier segments, optionally ending in an extension
_PATH = re.compile(
    r"\b((?:src|scripts|tests|benchmarks|docs)/[\w./-]*[\w])")


def referenced_paths(text):
    for m in _PATH.finditer(text):
        path = m.group(1).rstrip(".")
        yield path


def main() -> int:
    if not os.path.isdir(DOCS):
        print("check_docs: no docs/ directory", file=sys.stderr)
        return 1
    missing = []
    checked = 0
    for name in sorted(os.listdir(DOCS)):
        if not name.endswith(".md"):
            continue
        with open(os.path.join(DOCS, name)) as fh:
            text = fh.read()
        for path in referenced_paths(text):
            checked += 1
            if not os.path.exists(os.path.join(ROOT, path)):
                missing.append((name, path))
    if missing:
        for doc, path in missing:
            print(f"check_docs: docs/{doc} references missing {path}",
                  file=sys.stderr)
        return 1
    print(f"check_docs: {checked} path references OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
