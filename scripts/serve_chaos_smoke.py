"""CI smoke: the serving tier under chaos - WAL recovery after SIGKILL.

Run from scripts/ci.sh --smoke:

  PYTHONPATH=src python scripts/serve_chaos_smoke.py

The PR-9 acceptance run, at f64.  A child process serves a deterministic
fleet with a seeded :class:`~repro.resilience.faults.FaultPlan` installed
on every bucket engine:

* a transient NaN and a spin bit-flip mid-flight - the supervisor's
  rollback-retry absorbs both inside the child (the serving tier rides
  the PR 7 ladder unchanged);
* a ``crash`` fault that SIGKILLs the child mid-fleet.

The parent asserts the kill, rebuilds the server with
``SimServer.recover`` from the durable job journal, resubmits the SAME
fleet, and drains.  Acceptance:

* completed jobs deduplicate (no recomputation, no double charge);
* every surviving job's remaining observable stream and final state are
  BITWISE identical (f64) to an uninterrupted reference fleet - the
  interrupted job resumes from its committed watermark;
* zero steady-state recompiles across BOTH incarnations, from the
  runlog compile watchdog (recovery re-warms each bucket exactly once);
* the per-tenant accounting invariant (charged + idle == computed
  slot-steps) closes exactly over the combined runlog;
* the report CLI renders both the serving runlog and the journal.
"""
import json
import os
import signal
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

import jax  # noqa: E402

# f64 before any jax arrays exist (parent AND child import this module):
# the bitwise recovery-replay assertion is the acceptance criterion
jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.launch.serve import build_fleet  # noqa: E402
from repro.resilience import Fault, FaultPlan  # noqa: E402
from repro.serve import RequeuePolicy, ServeConfig, SimServer  # noqa: E402

N_JOBS = 4
CHUNK = 10
OBS_EVERY = 5

CHAOS = FaultPlan(faults=(
    Fault(kind="nan", step=12, leaf="force"),
    Fault(kind="bit_flip", step=22, leaf="spin", bit=62),
    Fault(kind="crash", step=35),
), seed=7)


def serve_cfg(tmp, *, faults=None):
    return ServeConfig(
        runlog=os.path.join(tmp, "chaos.jsonl"),
        workdir=os.path.join(tmp, "chaos"),
        journal_dir=os.path.join(tmp, "journal"),
        slots=2, chunk=CHUNK,
        requeue=RequeuePolicy(retries=1, backoff_s=0.0),
        faults=faults)


def child_main(tmp) -> None:
    srv = SimServer(serve_cfg(tmp, faults=CHAOS))
    for job in build_fleet(N_JOBS, CHUNK, OBS_EVERY):
        srv.submit(job)
    srv.drain()
    raise SystemExit("crash fault did not fire")


def report(path) -> str:
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.report", path],
        capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": "src" + os.pathsep
             + os.environ.get("PYTHONPATH", "")},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout


def main() -> int:
    tmp = tempfile.mkdtemp(prefix="serve-chaos-")

    # uninterrupted reference fleet (same packed shape, no faults)
    ref_cfg = ServeConfig(runlog=os.path.join(tmp, "ref.jsonl"),
                          workdir=os.path.join(tmp, "ref"),
                          slots=2, chunk=CHUNK)
    ref_srv = SimServer(ref_cfg)
    refs = [ref_srv.submit(job)
            for job in build_fleet(N_JOBS, CHUNK, OBS_EVERY)]
    ref_srv.drain()
    for g in refs:
        assert g.status == "done", (g.id, g.status, g.error)
    assert np.asarray(refs[0].final_state.spin).dtype == np.float64

    # --- child: serve the fleet into the chaos plan, die by SIGKILL ---
    child = subprocess.run(
        [sys.executable, __file__, "--child", tmp],
        capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": "src" + os.pathsep
             + os.environ.get("PYTHONPATH", "")})
    assert child.returncode == -signal.SIGKILL, \
        (child.returncode, child.stderr[-2000:])
    print("[serve_chaos_smoke] child SIGKILLed mid-fleet as planned")

    # --- parent: WAL recovery + idempotent resubmission ---------------
    srv = SimServer.recover(serve_cfg(tmp))    # no faults this time
    handles = [srv.submit(job)
               for job in build_fleet(N_JOBS, CHUNK, OBS_EVERY)]
    deduped = [h for h in handles if h.status == "done"]
    resumed = [h for h in handles if h.rows_base > 0]
    assert deduped, "no job deduplicated against the journal"
    assert resumed, "no job resumed from a committed watermark"
    print(f"[serve_chaos_smoke] recovered: {len(deduped)} deduplicated, "
          f"{len(resumed)} resumed from watermark, "
          f"{len(handles) - len(deduped) - len(resumed)} requeued")
    srv.drain()

    # bitwise recovery replay: remaining streams + final states (f64)
    for h, g in zip(handles, refs):
        assert h.status == "done", (h.id, h.status, h.error)
        if h.rows_streamed:
            for name, rows in g.observables.items():
                assert np.array_equal(
                    h.observables[name], rows[h.rows_base:]), \
                    f"{h.id} {name} diverges from the uninterrupted run"
        if h.final_state is not None:
            for leaf in ("pos", "vel", "spin", "step"):
                assert np.array_equal(
                    np.asarray(getattr(h.final_state, leaf)),
                    np.asarray(getattr(g.final_state, leaf))), \
                    f"{h.id} final {leaf} diverges"
    assert any(h.final_state is not None for h in resumed), \
        "no resumed job reached a comparable final state"
    print("[serve_chaos_smoke] remaining streams + final states "
          "bitwise vs uninterrupted fleet (f64)")

    # compile watchdog over BOTH incarnations: recovery re-warms each
    # bucket once; nothing recompiles in steady state
    acct = srv.accounting
    assert acct.recoveries == 1
    for bid, b in sorted(acct.buckets.items()):
        assert b["warmup_compiles"] >= 1, (bid, b)
        assert b["steady_compiles"] == 0, \
            f"bucket {bid} recompiled in steady state: {b}"
        print(f"[serve_chaos_smoke] bucket {bid}: {b['chunks']} chunks, "
              f"{b['warmup_compiles']} warmup / 0 steady compiles")

    # the accounting invariant closes exactly across the crash
    assert acct.consistent(), acct.summary()
    for tenant, t in sorted(acct.tenants.items()):
        print(f"[serve_chaos_smoke] tenant {tenant}: "
              f"{t['charged_steps']} slot-steps charged")

    # both reports render: runlog (with per-tenant table) and journal
    out = report(serve_cfg(tmp).runlog)
    assert "Per-tenant" in out, out
    jout = report(os.path.join(tmp, "journal", "journal.jsonl"))
    assert "commit" in jout and "recovered" in jout, jout
    print("[serve_chaos_smoke] reports render runlog + journal OK")
    print("serve chaos smoke: OK")
    return 0


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "--child":
        child_main(sys.argv[2])
    else:
        sys.exit(main())
