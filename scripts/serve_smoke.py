"""Serving smoke: the PR-8 acceptance run, at f64.

Drives the batched job server (:mod:`repro.serve`) end-to-end the way CI
wants it proven:

* >= 6 mixed-size jobs across >= 2 shape buckets (two geometries from
  ``repro.launch.serve.build_fleet``), heterogeneous (T, B) protocols;
* ZERO steady-state recompiles after one warmup chunk per bucket,
  asserted from the runlog's compile watchdog (the accounting replay
  splits each bucket's chunk records into warmup vs steady);
* every packed job's streamed observables and final state BITWISE equal
  to the same job through a single-slot server - at f64, where a 1-ulp
  fusion divergence cannot hide behind f32 noise;
* per-tenant accounting totals consistent with the engine's chunk
  records (charged + idle slot-steps == computed slot-steps).

Run directly (``scripts/ci.sh --smoke`` wires it in)::

    PYTHONPATH=src python scripts/serve_smoke.py
"""
import sys

import jax

# f64 before any jax arrays exist: the bitwise-parity assertion below is
# the acceptance criterion, and it must hold at full precision (the
# in-process test suite covers the same contract at default f32)
jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.launch.serve import build_fleet  # noqa: E402
from repro.serve import ServeConfig, SimServer  # noqa: E402


N_JOBS = 6
CHUNK = 10
OBS_EVERY = 5


def run_server(tmp, name, slots):
    cfg = ServeConfig(runlog=f"{tmp}/{name}.jsonl", workdir=f"{tmp}/{name}",
                      slots=slots, chunk=CHUNK)
    server = SimServer(cfg)
    handles = [server.submit(job)
               for job in build_fleet(N_JOBS, CHUNK, OBS_EVERY)]
    server.drain()
    return server, handles


def main() -> int:
    import tempfile
    tmp = tempfile.mkdtemp(prefix="serve-smoke-")
    packed, ph = run_server(tmp, "packed", slots=2)
    solo, sh = run_server(tmp, "solo", slots=1)

    for h in ph + sh:
        assert h.status == "done", f"{h.id}: {h.status} ({h.error})"
    buckets = {h.bucket for h in ph}
    assert len(buckets) >= 2, f"expected >= 2 shape buckets, got {buckets}"
    print(f"{len(ph)} jobs done across {len(buckets)} buckets")

    # f64 actually on (otherwise the parity assertion proves less)
    spin = np.asarray(ph[0].final_state.spin)
    assert spin.dtype == np.float64, spin.dtype

    # compile watchdog: one warmup per bucket, zero steady-state compiles
    acct = packed.accounting
    for bid, b in sorted(acct.buckets.items()):
        assert b["warmup_compiles"] >= 1, (bid, b)
        assert b["steady_compiles"] == 0, (
            f"bucket {bid} recompiled in steady state: {b}")
        print(f"bucket {bid}: {b['chunks']} chunks, "
              f"{b['warmup_compiles']} warmup / 0 steady compiles")

    # packed-vs-solo bitwise parity at f64, streams AND final states
    for h, g in zip(ph, sh):
        for name, rows in g.observables.items():
            assert np.array_equal(h.observables[name], rows), \
                f"{h.id} {name} diverges from solo"
        assert np.array_equal(h.times, g.times), h.id
        for leaf in ("pos", "vel", "spin", "step"):
            assert np.array_equal(
                np.asarray(getattr(h.final_state, leaf)),
                np.asarray(getattr(g.final_state, leaf))), \
                f"{h.id} final {leaf} diverges from solo"
    print("packed-vs-solo bitwise parity: OK (f64)")

    # accounting ledger closes: charged + idle == computed slot-steps
    assert acct.consistent(), acct.summary()
    assert acct.charged_steps + acct.idle_steps == acct.computed_slot_steps
    for tenant, t in sorted(acct.tenants.items()):
        assert t["jobs_done"] == t["jobs_submitted"]
        print(f"tenant {tenant}: {t['jobs_done']} jobs, "
              f"{t['charged_steps']} slot-steps charged")
    print("serve smoke: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
