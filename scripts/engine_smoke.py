"""CI smoke: schedule-driven sharded engine chunk + checkpoint/resume.

Run under 2 forced host devices (scripts/ci.sh --smoke):

  XLA_FLAGS=--xla_force_host_platform_device_count=2 \
      PYTHONPATH=src python scripts/engine_smoke.py

Drives one field-cooling protocol chunk through the shard_map domain plan
(the schedule evaluated INSIDE the compiled scan), checkpoints at the
chunk boundary, restores into a fresh engine, and asserts the resumed
trajectory is bitwise identical to an uninterrupted run - the smallest
end-to-end proof that the engine's schedule, sharding, and
checkpoint-restart axes compose.

The first run also exercises the telemetry layer: the runlog JSONL must
contain per-chunk records whose halo bytes match the engine's run-scoped
ledger exactly, whose compile count drops to 0 after the warmup chunk,
and which carry an energy-drift signal and a health verdict; then
``python -m repro.launch.report`` must render the runlog without error.
"""
import os
import subprocess
import sys
import tempfile

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=2")
sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.hamiltonian import HeisenbergDMIModel  # noqa: E402
from repro.ensemble import protocol  # noqa: E402
from repro.md.engine import Engine  # noqa: E402
from repro.md.integrator import IntegratorConfig  # noqa: E402
from repro.md.lattice import simple_cubic  # noqa: E402
from repro.md.state import init_state  # noqa: E402
from repro.parallel.plan import Sharded  # noqa: E402


def make_engine():
    lat = simple_cubic()
    st = init_state(lat, (8, 6, 6), temperature=300.0, spin_init="helix_x",
                    key=jax.random.PRNGKey(0))
    temp, field = protocol.field_cooling(
        300.0, 50.0, 25.0, t_hold=0.004, t_ramp=0.02)
    return Engine(
        potential=HeisenbergDMIModel(d0=0.01),
        cfg=IntegratorConfig(dt=2e-3, spin_alpha=0.05, lattice_gamma=1.0),
        state=st, masses=jnp.asarray(lat.masses),
        magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0, capacity=16,
        skin=0.2, plan=Sharded(), temperature=temp, field=field,
        observables=("energy", "magnetization", "charge"))


def check_runlog(path, eng):
    """Assert the telemetry contract on the smoke run's JSONL stream."""
    from repro.telemetry.runlog import read_runlog

    events = read_runlog(path)
    kinds = [e["event"] for e in events]
    assert kinds[0] == "run_start" and kinds[-1] == "run_end", kinds
    chunks = [e for e in events if e["event"] == "chunk"]
    assert len(chunks) >= 1, "runlog has no chunk records"
    ledger = eng.halo_ledger.snapshot()
    for c in chunks:
        assert c["halo"] == ledger, (
            f"runlog halo record diverges from the run-scoped ledger:\n"
            f"  record: {c['halo']}\n  ledger: {ledger}")
        assert "e_drift" in c["health"], c["health"]
        assert c["verdict"] in ("ok", "warn"), c["verdict"]
    assert chunks[0]["compiles"] >= 1, "warmup chunk recorded no compile"
    for c in chunks[1:]:
        assert c["compiles"] == 0, (
            f"recompile in steady state: chunk {c['chunk']} "
            f"compiled {c['compiles']}x")
    end = events[-1]
    assert end["status"] == "ok", end

    rep = subprocess.run(
        [sys.executable, "-m", "repro.launch.report", path],
        capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": "src" + os.pathsep + os.environ.get(
                 "PYTHONPATH", "")})
    assert rep.returncode == 0, f"report CLI failed:\n{rep.stderr}"
    assert "Run report" in rep.stdout, rep.stdout
    return len(chunks)


def main():
    assert jax.device_count() >= 2, (
        f"engine smoke needs 2 devices, got {jax.device_count()} - set "
        "XLA_FLAGS=--xla_force_host_platform_device_count=2")
    key = jax.random.PRNGKey(7)
    a = make_engine()
    with tempfile.TemporaryDirectory() as d:
        runlog = os.path.join(d, "smoke.jsonl")
        a.run(20, key, chunk=10, telemetry=runlog)
        n_chunks = check_runlog(runlog, a)
    with tempfile.TemporaryDirectory() as d:
        b = make_engine()
        b.run(10, key, chunk=10, checkpoint_dir=d)
        c = make_engine()
        resume_key = c.restore(d)
        c.run(10, resume_key, chunk=10)
    for name in ("pos", "vel", "spin"):
        va, vc = getattr(a.state, name), getattr(c.state, name)
        assert bool(jnp.all(va == vc)), f"{name} not bitwise after resume"
    assert a.trace.values["charge"].shape == (2,)
    print("engine smoke OK: schedule-driven sharded chunk on "
          f"{jax.device_count()} devices, checkpoint/resume bitwise, "
          f"Q trace {a.trace.values['charge'].tolist()}, "
          f"runlog {n_chunks} chunk records verified + report rendered")


if __name__ == "__main__":
    main()
