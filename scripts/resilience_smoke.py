"""CI smoke: supervised fault recovery + kill-and-resume.

Run from scripts/ci.sh --smoke:

  PYTHONPATH=src python scripts/resilience_smoke.py

Two end-to-end recovery paths on the flat plan (small enough for CI, and
the supervisor logic is plan-independent - the sharded variants live in
tests/test_resilience.py):

1. supervised retry: a seeded NaN fault is injected mid-run, the health
   gate raises, the supervisor rolls back to the newest checkpoint and
   retries; the recovered trajectory must be BITWISE identical to an
   uninterrupted run, the retry must reuse the compiled chunk (0 compiles
   in every chunk record after the rollback), and the runlog must carry
   the structured fault_injected / rollback / retry / recovered records
   which ``python -m repro.launch.report`` renders;

2. kill-and-resume: a crash fault SIGKILLs a child run mid-trajectory;
   the parent asserts the kill, restores the newest checkpoint (at most
   one chunk of work lost), and the resumed trajectory is bitwise too.
"""
import json
import os
import signal
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.hamiltonian import HeisenbergDMIModel  # noqa: E402
from repro.ckpt.checkpoint import latest_step  # noqa: E402
from repro.md.engine import Engine  # noqa: E402
from repro.md.integrator import IntegratorConfig  # noqa: E402
from repro.md.lattice import simple_cubic  # noqa: E402
from repro.md.state import init_state  # noqa: E402
from repro.resilience import (Fault, FaultPlan, Supervisor,  # noqa: E402
                              SupervisorConfig, install_faults)
from repro.telemetry import (HealthConfig, Telemetry,  # noqa: E402
                             read_runlog)


def make_engine():
    lat = simple_cubic()
    st = init_state(lat, (4, 4, 4), temperature=300.0, spin_init="helix_x",
                    key=jax.random.PRNGKey(3))
    return Engine(potential=HeisenbergDMIModel(d0=0.008),
                  cfg=IntegratorConfig(dt=2e-3, spin_alpha=0.05,
                                       lattice_gamma=1.0),
                  state=st, masses=jnp.asarray(lat.masses),
                  magnetic=jnp.asarray(lat.moments) > 0, cutoff=5.0,
                  capacity=8, skin=0.2,
                  observables=("energy", "magnetization"))


def assert_bitwise(a, b, what):
    for leaf in ("pos", "vel", "spin"):
        x, y = np.asarray(getattr(a, leaf)), np.asarray(getattr(b, leaf))
        assert np.array_equal(x, y), \
            f"{what}: {leaf} differs (max {np.abs(x - y).max()})"


def main():
    tmp = tempfile.mkdtemp(prefix="resilience_smoke_")
    key = jax.random.PRNGKey(0)

    # reference: uninterrupted run
    ref = make_engine()
    ref.run(40, key, chunk=10)

    # --- 1. supervised NaN retry --------------------------------------
    log = os.path.join(tmp, "run.jsonl")
    eng = make_engine()
    install_faults(eng, FaultPlan(faults=(
        Fault(kind="nan", step=25, leaf="force"),)), runlog=log)
    sup = Supervisor(SupervisorConfig(max_retries=2))
    out = sup.run(eng, 40, key, chunk=10,
                  checkpoint_dir=os.path.join(tmp, "ck"),
                  telemetry=Telemetry(runlog=log, health=HealthConfig()))
    events = [e["event"] for e in sup.events]
    assert events == ["rollback", "retry", "recovered"], events
    assert_bitwise(ref.state, out, "supervised retry")

    records = read_runlog(log)
    logged = [r["event"] for r in records]
    for ev in ("fault_injected", "rollback", "retry", "recovered"):
        assert ev in logged, logged
    first_rb = next(i for i, r in enumerate(records)
                    if r["event"] == "rollback")
    retry_compiles = [r["compiles"] for r in records[first_rb:]
                      if r["event"] == "chunk"]
    assert retry_compiles and all(c == 0 for c in retry_compiles), \
        f"retry recompiled: {retry_compiles}"
    print(f"[resilience_smoke] supervised retry OK "
          f"(bitwise, retry compiles {retry_compiles})")

    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.report", log],
        capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": "src" + os.pathsep
             + os.environ.get("PYTHONPATH", "")},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "rollback" in r.stdout and "recovered" in r.stdout, r.stdout
    print("[resilience_smoke] report renders recovery events OK")

    # --- 2. kill-and-resume -------------------------------------------
    ck2 = os.path.join(tmp, "ck_crash")
    child = subprocess.run(
        [sys.executable, __file__, "--crash-child", ck2],
        capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": "src" + os.pathsep
             + os.environ.get("PYTHONPATH", "")})
    assert child.returncode == -signal.SIGKILL, \
        (child.returncode, child.stderr[-2000:])
    last = latest_step(ck2)
    assert last is not None and 40 - last <= 20, \
        f"more than one chunk lost (newest checkpoint {last})"
    eng2 = make_engine()
    key2 = eng2.restore(ck2)
    eng2.run(40 - int(eng2._step_now()), key2, chunk=10)
    assert_bitwise(ref.state, eng2.state, "kill-and-resume")
    print(f"[resilience_smoke] kill-and-resume OK "
          f"(killed run checkpointed through step {last}, bitwise)")


def crash_child(ck):
    eng = make_engine()
    install_faults(eng, FaultPlan(faults=(Fault(kind="crash", step=25),)))
    eng.run(40, jax.random.PRNGKey(0), chunk=10,
            checkpoint_dir=ck, checkpoint_every=1)
    raise SystemExit("crash fault did not fire")


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "--crash-child":
        crash_child(sys.argv[2])
    else:
        main()
